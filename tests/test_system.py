"""End-to-end behaviour tests for the paper's system: scheduler -> planner
-> model runtime -> serving, wired together."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.core import paper_spg, paper_topology, schedule_hvlb_cc
from repro.data import SyntheticTokenPipeline
from repro.models.params import init_params
from repro.optim import AdamWConfig
from repro.optim.adamw import init_opt_state
from repro.planner import (pipeline_graph, plan_placement,
                           tpu_slice_topology)
from repro.serve import DSMSEngine, Query
from repro.train import make_train_step


def test_end_to_end_schedule_to_training():
    """The paper's planner chooses a placement; training runs under it."""
    cfg = reduced_config(get_arch("qwen3-8b"))
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, vocab=128)
    # 1. plan the pipeline placement with the paper's algorithm
    g = pipeline_graph(get_arch("qwen3-8b"), SHAPES["train_4k"], 4)
    tg = tpu_slice_topology(n_slices=4, chips_per_slice=64, pods=1)
    plan = plan_placement(g, tg, "hvlb_b")
    plan.schedule.validate()
    assert plan.makespan_s > 0 and len(plan.stage_map) >= 1
    # 2. run real training steps (the compute the plan schedules)
    shape = ShapeConfig("t", 32, 2, "train")
    pipe = SyntheticTokenPipeline(cfg, shape)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=2,
                                                    total_steps=4)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    losses = []
    for s in range(4):
        params, opt, info = step(params, opt, pipe.device_batch(s))
        losses.append(float(info["loss"]))
    assert all(np.isfinite(l) for l in losses)


def test_end_to_end_dsms_serving_with_imprecise_query():
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DSMSEngine(cfg, params, batch_size=2, max_seq=16)
    eng.register(Query("alert",
                       mandatory=lambda lg: jnp.max(lg[:, -1], -1)))
    eng.register(Query("topk",
                       mandatory=lambda lg: jax.lax.top_k(lg[:, -1], 3),
                       optional=lambda r: (r[0], r[1]),
                       optional_ratio=0.1))
    toks = np.zeros(2, np.int64)
    for _ in range(4):
        res = eng.step(toks)
        toks = res.tokens
        assert res.tokens.shape == (2,)
        assert set(res.query_outputs) == {"alert", "topk"}
    assert res.precise["alert"] is True      # no optional part -> precise


# the deprecated shim is called deliberately (its warning is pinned by
# tests/test_deprecation.py); filter it so the suite stays clean under
# the CI's -W error::DeprecationWarning
@pytest.mark.filterwarnings("ignore:schedule_h:DeprecationWarning")
def test_paper_example_through_planner_api():
    """The core algorithms remain exact through the public API."""
    res = schedule_hvlb_cc(paper_spg(), paper_topology(), variant="B",
                           alpha_max=3.0, period=150.0)
    assert res.best.makespan == 62.0
