"""Unit + property tests for model layers and the sharding rule engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch, reduced_config
from repro.models import layers as L
from repro.models.params import init_params
from repro.models.sharding import spec_for, use_sharding


def test_rope_preserves_norm():
    """Rotary embedding is a rotation: per-pair norms are invariant."""
    cfg = reduced_config(get_arch("qwen3-8b"))
    B, S, H, dh = 2, 16, 4, cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, dh))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q2, k2 = L.apply_rope(cfg, q, k, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(q), axis=-1),
                               np.linalg.norm(np.asarray(q2), axis=-1),
                               rtol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(k), axis=-1),
                               np.linalg.norm(np.asarray(k2), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """Attention scores under RoPE depend only on relative positions."""
    cfg = reduced_config(get_arch("qwen3-8b"))
    B, H, dh = 1, 1, cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 2, H, dh))
    pos_a = jnp.array([[3, 7]])
    pos_b = jnp.array([[13, 17]])       # same offset (4)
    qa, ka = L.apply_rope(cfg, q, q, pos_a)
    qb, kb = L.apply_rope(cfg, q, q, pos_b)
    sa = float(jnp.vdot(qa[0, 0, 0], ka[0, 1, 0]))
    sb = float(jnp.vdot(qb[0, 0, 0], kb[0, 1, 0]))
    assert abs(sa - sb) < 1e-3


def test_chunked_attention_matches_full():
    """The online-softmax q-chunked path equals full attention."""
    cfg = reduced_config(get_arch("phi3-mini-3.8b"))
    B, S, K, G, dh = 1, L.ATTN_CHUNK * 2, 2, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, G, dh)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, dh)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, dh))
    full = L._sdpa_full(q, k, v, True, 0)
    chunked = L._sdpa_chunked(q, k, v, True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_combine_conserves_gate_weight():
    """Tokens kept within capacity come back weighted by normalized gates;
    with identity experts the output is a convex combination bound."""
    cfg = reduced_config(get_arch("olmoe-1b-7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))["blocks"]["moe"]
    p = {k: v[0] for k, v in params.items()}     # layer 0
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    out = L.moe(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # zero input -> zero output (routing of zeros)
    out0 = L.moe(cfg, p, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-5)


def test_causal_conv_matches_explicit():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    out, _ = L._causal_conv(x, w, None)
    # explicit: y[t] = sum_i w[i] * x[t - (k-1) + i]
    xp = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    ref = sum(xp[:, i:i + 16] * w[i] for i in range(4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_spec_for_drops_nondivisible_axes():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_sharding(mesh):
        # 14 heads % 1 == 0 on this mesh, always keeps
        s = spec_for(("batch", "seq", "heads", None), (4, 8, 14, 16))
        assert len(s) == 4


def test_spec_for_no_double_axis_use():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_sharding(mesh):
        s = spec_for(("p_experts", "p_in", "p_ff"), (4, 8, 16))
        used = [a for part in s if part for a in
                (part if isinstance(part, tuple) else (part,))]
        assert len(used) == len(set(used))


@given(b=st.integers(1, 3), s=st.sampled_from([8, 16]),
       di=st.sampled_from([8, 16]), n=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_ssm_chunk_scan_matches_naive(b, s, di, n):
    """The chunked scan reduction equals the naive recurrence."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(b * 100 + s))
    dA = jnp.exp(-jax.random.uniform(k1, (b, 2, s // 2, di, n)))
    dBx = jax.random.normal(k2, (b, 2, s // 2, di, n))
    hs = L._ssm_chunk_scan(dA, dBx).reshape(b, s, di, n)
    # naive
    dA_f = dA.reshape(b, s, di, n)
    dBx_f = dBx.reshape(b, s, di, n)
    h = jnp.zeros((b, di, n))
    outs = []
    for t in range(s):
        h = dA_f[:, t] * h + dBx_f[:, t]
        outs.append(h)
    ref = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
