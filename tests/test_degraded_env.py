"""Degraded-environment behaviour: a broken/missing/hung device backend
demotes down the fallback chain (pallas -> vector -> scalar) instead of
failing the session, lands on a bit-identical plan (decisions are
backend-invariant), records the demotion on ``Plan.fallback`` and warns
once per process."""
import numpy as np
import pytest

import repro.core.api as api_mod
import repro.core.backends as backends_mod
from repro.core import (HVLB_CC_B, Scheduler, WaveTimeoutError,
                        paper_topology, random_spg)


def _case(seed=0, n=20):
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(n, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    return tg, g


def _pol():
    return HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)


def _scalar_reference(tg, g):
    return Scheduler(tg, policy=_pol(), backend="scalar").submit(g)


def _assert_same_decisions(plan, ref):
    assert np.array_equal(plan.schedule.proc, ref.schedule.proc)
    assert np.array_equal(plan.schedule.start, ref.schedule.start)
    assert np.array_equal(plan.schedule.finish, ref.schedule.finish)


def test_pallas_without_jax_demotes_at_resolve_time(monkeypatch):
    """backend='pallas' on a jax-free install must not kill the session:
    the request demotes to the NumPy chain with a recorded reason."""
    tg, g = _case()
    monkeypatch.setattr(backends_mod, "_pallas_available", lambda: False)
    monkeypatch.delitem(backends_mod.BACKENDS, "pallas", raising=False)
    monkeypatch.setattr(api_mod, "_FALLBACK_WARNED", set())
    sched = Scheduler(tg, policy=_pol(), backend="pallas")
    with pytest.warns(RuntimeWarning, match="pallas"):
        plan = sched.submit(g)
    assert plan.fallback is not None and len(plan.fallback) == 1
    src, dst, reason = plan.fallback[0]
    assert src == "pallas" and dst in ("vector", "scalar")
    assert "jax" in reason
    assert plan.backend == dst
    _assert_same_decisions(plan, _scalar_reference(tg, g))


def test_pallas_kernel_failure_demotes_at_plan_time(monkeypatch):
    """Per-wave path: an injected ``evaluate_batch`` fault demotes the
    plan (scan disabled so the wave kernel actually runs)."""
    pytest.importorskip("jax")
    from repro.core.backends.pallas import PallasBackend

    def _boom(self, js):
        raise RuntimeError("injected kernel failure")

    monkeypatch.setenv("REPRO_PALLAS_SCAN", "0")
    monkeypatch.setattr(PallasBackend, "evaluate_batch", _boom)
    monkeypatch.setattr(api_mod, "_FALLBACK_WARNED", set())
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol(), backend="pallas")
    with pytest.warns(RuntimeWarning, match="injected kernel failure"):
        plan = sched.submit(g)
    assert plan.fallback is not None
    assert plan.fallback[0][0] == "pallas"
    assert "injected kernel failure" in plan.fallback[0][2]
    assert plan.backend in ("vector", "scalar")
    _assert_same_decisions(plan, _scalar_reference(tg, g))


def test_pallas_scan_failure_demotes_at_plan_time(monkeypatch):
    """Scan path: a fault inside the whole-schedule dispatch demotes the
    plan exactly like a per-wave kernel fault."""
    pytest.importorskip("jax")
    from repro.core.backends.pallas import PallasBackend

    def _boom(self, waves, alphas):
        raise RuntimeError("injected scan failure")

    monkeypatch.setattr(PallasBackend, "_scan_dispatch", _boom)
    monkeypatch.setattr(api_mod, "_FALLBACK_WARNED", set())
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol(), backend="pallas")
    with pytest.warns(RuntimeWarning, match="injected scan failure"):
        plan = sched.submit(g)
    assert plan.fallback is not None
    assert plan.fallback[0][0] == "pallas"
    assert "injected scan failure" in plan.fallback[0][2]
    assert plan.backend in ("vector", "scalar")
    _assert_same_decisions(plan, _scalar_reference(tg, g))


def test_wave_timeout_demotes_device_backend(monkeypatch):
    """An (effectively) hung pallas wave trips the watchdog and demotes;
    the NumPy backends never run under the watchdog."""
    pytest.importorskip("jax")
    monkeypatch.setattr(api_mod, "_FALLBACK_WARNED", set())
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol(), backend="pallas",
                      wave_timeout=1e-9)
    with pytest.warns(RuntimeWarning, match="WaveTimeoutError"):
        plan = sched.submit(g)
    assert plan.fallback is not None
    assert plan.fallback[0][0] == "pallas"
    assert plan.backend in ("vector", "scalar")
    _assert_same_decisions(plan, _scalar_reference(tg, g))


def test_wave_timeout_ignored_by_numpy_backends():
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol(), backend="scalar",
                      wave_timeout=1e-9)
    plan = sched.submit(g)                  # no watchdog, no demotion
    assert plan.fallback is None
    _assert_same_decisions(plan, _scalar_reference(tg, g))


def test_wave_timeout_error_shape():
    e = WaveTimeoutError(3, 0.5, 0.1)
    assert e.wave == 3 and "watchdog" in str(e)


def test_nondevice_backend_errors_are_not_swallowed():
    """Only device backends demote: an unknown explicit backend raises."""
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol())
    with pytest.raises(ValueError, match="unknown backend"):
        sched.submit(g, backend="gpu3000")


def test_fallback_warns_only_once(monkeypatch):
    monkeypatch.setattr(backends_mod, "_pallas_available", lambda: False)
    monkeypatch.delitem(backends_mod.BACKENDS, "pallas", raising=False)
    monkeypatch.setattr(api_mod, "_FALLBACK_WARNED", set())
    tg, g = _case()
    sched = Scheduler(tg, policy=_pol(), backend="pallas")
    with pytest.warns(RuntimeWarning):
        sched.submit(g)
    _, g2 = _case(seed=1)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")            # a second warn would raise
        plan = sched.submit(g2)
    assert plan.fallback is not None        # still recorded on the plan
