"""Service chaos harness (ISSUE 8 satellite): seeded random request
scripts — registration bursts, drift updates, resource faults and
restores, interleaved across tenants — driven through
:class:`repro.service.SchedulerService`.

Invariants asserted:

  * every response is either ``ok`` or a *structured* error with a
    known protocol code — the service never wedges or raises;
  * after every burst, each tenant's live fleet schedule passes the
    independent :func:`repro.core.schedule_violations` oracle under the
    active fault spec;
  * the final state matches a direct fresh single-session
    ``Scheduler.submit_many`` bit-identically (same drifted graphs,
    same recorded faults, same pinned period) — and when the service
    ends infeasible, the fresh session must raise
    :class:`InfeasibleScheduleError` too.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.core import (HVLB_CC_B, InfeasibleScheduleError, Scheduler,
                        fully_switched_topology, random_spg,
                        schedule_violations)
from repro.service import SchedulerService

_P = 4
_POLICY = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
_KNOWN_CODES = {"infeasible", "bad-request", "no-graphs"}


def _topology():
    return fully_switched_topology(
        _P, rates=[1.0, 1.2, 0.9, 1.1],
        link_speeds=[1.0, 2.0, 1.5, 1.2])


def _script(rng, tg, tenant, n_ops):
    """A seeded request script: list of bursts, burst = list of
    (kind, params)."""
    links = tg.all_links()
    ops = []
    n_graphs = 0
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.30 or n_graphs == 0:
            n = int(rng.integers(8, 12))
            g = random_spg(n, rng, tg=tg, outdeg_constraint=True)
            g.name = f"{tenant}-g{n_graphs}"
            ops.append(("register", {"graph": g, "name": g.name}))
            n_graphs += 1
        elif r < 0.55:
            gname = f"{tenant}-g{int(rng.integers(n_graphs))}"
            ops.append(("update", {
                "graph": gname,
                "task_rates": {int(rng.integers(8)):
                               float(rng.uniform(0.7, 1.6))}}))
        elif r < 0.60:
            # deliberately invalid: must fail alone (bad-request) with
            # zero effect on batch-mates or the final-state oracle
            gname = f"{tenant}-g{int(rng.integers(n_graphs))}"
            ops.append(("update", {
                "graph": gname, "task_rates": {999: 1.5}}))
        elif r < 0.68:
            ops.append(("update", {
                "link_speed": {links[int(rng.integers(len(links)))]:
                               float(rng.uniform(0.8, 1.5))}}))
        elif r < 0.76:
            ops.append(("mark_failed",
                        {"proc": int(rng.integers(_P))}
                        if rng.random() < 0.5 else
                        {"link": links[int(rng.integers(len(links)))]}))
        elif r < 0.84:
            if rng.random() < 0.5:
                ops.append(("degrade",
                            {"link": links[int(rng.integers(len(links)))],
                             "factor": float(rng.uniform(1.2, 3.0))}))
            else:                  # compute spike on a live fleet task
                gname = f"{tenant}-g{int(rng.integers(n_graphs))}"
                ops.append(("degrade",
                            {"graph": gname,
                             "task": int(rng.integers(8)),
                             "factor": float(rng.uniform(1.1, 2.0))}))
        elif r < 0.92:
            ops.append(("restore",
                        {"proc": int(rng.integers(_P))}
                        if rng.random() < 0.5 else
                        {"link": links[int(rng.integers(len(links)))]}))
        else:
            ops.append(("plan", {}))
    # group into bursts of 1-4 adjacent ops
    bursts, i = [], 0
    while i < len(ops):
        k = int(rng.integers(1, 5))
        bursts.append(ops[i:i + k])
        i += k
    return bursts


def _check_live_fleets(svc):
    """The per-burst oracle: every live fleet schedule validates clean
    under the tenant's active fault spec."""
    for t in svc._tenants.values():
        if t.fleet is not None and t.sched is not None:
            v = schedule_violations(t.fleet.schedule, t.sched.faults)
            assert v == [], v


async def _drive(svc, scripts):
    for burst_idx in range(max(len(b) for b in scripts.values())):
        futs = []
        for tenant, bursts in scripts.items():
            if burst_idx >= len(bursts):
                continue
            for kind, params in bursts[burst_idx]:
                futs.append(asyncio.ensure_future(
                    svc.request(tenant, kind, **params)))
        for resp in await asyncio.gather(*futs):
            assert resp.ok or resp.error["code"] in _KNOWN_CODES, resp
        _check_live_fleets(svc)
    return {tenant: await svc.request(tenant, "plan")
            for tenant in scripts}


@pytest.mark.parametrize("seed", range(6))
def test_chaos_script_matches_fresh_scheduler(seed):
    tg = _topology()
    rng = np.random.default_rng(7_000 + seed)
    scripts = {tenant: _script(rng, tg, tenant, n_ops=12)
               for tenant in ("carA", "carB")}

    svc = SchedulerService(tg, _POLICY, workers=3)
    finals = asyncio.run(_drive(svc, scripts))

    for tenant, resp in finals.items():
        t = svc._tenants[tenant]
        fresh = Scheduler(
            t.topology,
            policy=dataclasses.replace(
                _POLICY,
                period=resp.result["period"] if resp.ok else None),
            faults=t.fault_records)
        if not resp.ok:
            assert resp.error["code"] == "infeasible", resp
            with pytest.raises(InfeasibleScheduleError):
                fresh.submit_many(list(t.graphs.values()))
            continue
        fleet = fresh.submit_many(list(t.graphs.values()))
        assert float(fleet.makespan) == resp.result["makespan"]
        for k, name in enumerate(t.graphs):
            sub = fleet.subschedule(k)
            view = asyncio.run(_plan_view(svc, tenant, name))
            assert view["proc"] == [int(x) for x in sub.proc]
            assert view["start"] == [float(x) for x in sub.start]
            assert view["finish"] == [float(x) for x in sub.finish]
        assert schedule_violations(fleet.schedule, fresh.faults) == []


async def _plan_view(svc, tenant, name):
    resp = await svc.request(tenant, "plan", graph=name)
    assert resp.ok, resp.error
    return resp.result
