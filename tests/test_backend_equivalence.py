"""Scalar vs vector vs pallas candidate-evaluation backends.

The vector backend re-expresses the engine's per-processor candidate
loop as (P,)-batch array ops, reassociating only exact operations
(IEEE max), so its schedules — start/finish floats, message routes,
per-link intervals, alpha-sweep curves, crossing bounds, IC holes, and
decision-replay counters — must equal the scalar backend's exactly.
No tolerance in the scalar/vector half of this file.

The Pallas backend (interpret mode on CPU runners) performs the same
float64 arithmetic inside a device kernel; its contract is *decision
identity* — same winner tuples, hence same processor assignments,
routes, and replay counters — with makespans/floats equal within float
tolerance (in practice they come out bit-identical on the interpret
path, but only decision identity is pinned; see DESIGN §5).

Covered: the paper worked example (multi-route topology, CTML
quantization), the 200-graph mixed-config corpus, wide single-route
topologies (P = 8, 16 — where "auto" actually picks vector), all four
policies including HVLB_CC_IC schedule holes / precision, and
``Scheduler.update`` trace replay across backends (traces are
backend-portable).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, HVLB_CC_IC,
                        CompiledInstance, Scheduler, paper_spg,
                        paper_topology, random_spg, resolve_backend_name)
from repro.core.backends import AUTO_VECTOR_MIN_P, BackendCompatError
from repro.core.engine import DEFAULT_BATCH_MAX
from repro.core.backends.vector import VectorBackend
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.topology import Topology, fully_switched_topology

RATE_PATTERNS = [(1.0, 0.67, 0.83), (0.83, 0.67, 1.0), (0.67, 0.83, 1.0)]

POLICIES = [
    HSV_CC(),
    HVLB_CC_A(alpha_max=1.0, alpha_step=0.25, period=150.0),
    HVLB_CC_B(alpha_max=1.0, alpha_step=0.25, period=150.0),
    HVLB_CC_IC(alpha_max=1.0, alpha_step=0.25, period=150.0),
]


def assert_identical(a, b):
    assert np.array_equal(a.proc, b.proc)
    assert np.array_equal(a.start, b.start)        # exact, no tolerance
    assert np.array_equal(a.finish, b.finish)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route
        assert ma.intervals == mb.intervals        # exact floats
        assert (ma.src_proc, ma.dst_proc) == (mb.src_proc, mb.dst_proc)


def assert_plans_identical(pa, pb):
    assert_identical(pa.schedule, pb.schedule)
    assert pa.period == pb.period
    if pa.sweep is not None:
        assert np.array_equal(pa.sweep.alphas, pb.sweep.alphas)
        assert np.array_equal(pa.sweep.makespans, pb.sweep.makespans)
        assert pa.sweep.best_alpha == pb.sweep.best_alpha
    if pa.holes is not None:
        assert pa.holes == pb.holes                # exact, inf included


def _case(seed: int):
    """Same mixed-config generator as tests/test_engine_equivalence.py."""
    rng = np.random.default_rng(seed)
    rates = RATE_PATTERNS[seed % 3]
    tg = paper_topology(rates=rates)
    ccr = [0.1, 1.0, 10.0][(seed // 3) % 3]
    constrained = (seed // 9) % 2 == 0
    n = int(rng.integers(8, 31))
    g = random_spg(n, rng, ccr=ccr, tg=tg, outdeg_constraint=constrained)
    return g, tg


def _wide(P: int, seed: int, n: int = 28):
    rng = np.random.default_rng(seed)
    tg = fully_switched_topology(P, rates=rng.uniform(0.6, 1.2, size=P),
                                 link_speeds=rng.uniform(0.5, 3.0, size=P))
    g = random_spg(n, rng, ccr=1.0, tg=tg, max_in=3, max_out=6)
    return g, tg


# ---------------------------------------------------------------- paper
@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
def test_paper_example_policies_backend_identical(policy):
    g, tg = paper_spg(), paper_topology()
    pa = Scheduler(tg, backend="scalar").submit(g, policy)
    pb = Scheduler(tg, backend="vector").submit(g, policy)
    assert pa.backend == "scalar" and pb.backend == "vector"
    assert_plans_identical(pa, pb)
    if isinstance(policy, HVLB_CC_IC):
        # unbounded exit holes and degradation curves match exactly
        assert any(np.isinf(h) for h in pa.holes.values())
        for t in pa.holes:
            for lam in (0.5, 2.0, 100.0):
                assert pa.precision(t, lam) == pb.precision(t, lam)


# ------------------------------------------------------------- corpus
@pytest.mark.parametrize("seed", range(200))
def test_backend_equivalence_random(seed):
    """Bit-identical single passes and crossing bounds on the 200-graph
    corpus (paper-style multi-route topology, both backends sharing one
    compiled instance)."""
    g, tg = _case(seed)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 0.85):
        s = inst.schedule(q, alpha=alpha, backend="scalar")
        v = inst.schedule(q, alpha=alpha, backend="vector")
        assert_identical(s, v)
        sb, bs = inst.schedule_with_bound(q, alpha, backend="scalar")
        vb, bv = inst.schedule_with_bound(q, alpha, backend="vector")
        assert_identical(sb, vb)
        assert bs == bv                            # exact bound float


@pytest.mark.parametrize("seed", range(0, 200, 13))
def test_policy_equivalence_random(seed):
    """All four policies produce identical plans under both backends on a
    corpus slice (sweeps, best schedules, IC holes).  Where a policy's
    HPRV_A queue cannot order an unconstrained graph (the Section-3.2
    failure mode), both backends must fail the same way."""
    from repro.core import SchedulingFailure

    g, tg = _case(seed)
    for policy in POLICIES:
        try:
            pa = Scheduler(tg, backend="scalar").submit(g, policy)
        except SchedulingFailure:
            with pytest.raises(SchedulingFailure):
                Scheduler(tg, backend="vector").submit(g, policy)
            continue
        pb = Scheduler(tg, backend="vector").submit(g, policy)
        assert_plans_identical(pa, pb)


@pytest.mark.parametrize("P", [8, 16])
@pytest.mark.parametrize("seed", [3, 17])
def test_backend_equivalence_wide_topology(P, seed):
    """Equivalence where auto-selection actually picks vector."""
    g, tg = _wide(P, seed)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 1.2):
        assert_identical(inst.schedule(q, alpha=alpha, backend="scalar"),
                         inst.schedule(q, alpha=alpha, backend="vector"))
        sb, bs = inst.schedule_with_bound(q, alpha, backend="scalar")
        vb, bv = inst.schedule_with_bound(q, alpha, backend="vector")
        assert_identical(sb, vb)
        assert bs == bv
    pa = Scheduler(tg, backend="scalar").submit(
        g, HVLB_CC_B(alpha_max=1.0, alpha_step=0.25))
    pb = Scheduler(tg, backend="vector").submit(
        g, HVLB_CC_B(alpha_max=1.0, alpha_step=0.25))
    assert_plans_identical(pa, pb)


# ------------------------------------------------------- update replay
@pytest.mark.parametrize("seed,factor", [(0, 0.8), (2, 1.5), (5, 0.7)])
def test_update_replay_backend_identical(seed, factor):
    """update() replays identically under both backends: same suffix
    start, same replay counters, bit-identical plans."""
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    plans = {}
    for backend in ("scalar", "vector"):
        sched = Scheduler(tg, policy=policy, backend=backend)
        plan = sched.submit(g)
        task = int(np.argmax(plan.schedule.start))
        plans[backend] = sched.update(task_rates={task: factor})
    ua, ub = plans["scalar"], plans["vector"]
    assert_plans_identical(ua, ub)
    assert dataclasses.asdict(ua.replay) == dataclasses.asdict(ub.replay)


def test_update_resumes_trace_recorded_by_other_backend():
    """Traces are backend-portable: a trace recorded under scalar replays
    bit-identically when the update runs under vector (and vice versa)."""
    rng = np.random.default_rng(11)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g, backend="scalar")
    task = int(np.argmax(plan.schedule.start))
    upd = sched.update(task_rates={task: 0.8}, backend="vector")
    assert upd.backend == "vector"
    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_identical(upd.schedule, fresh.schedule)


# ------------------------------------------------------- auto-selection
ONE_POINT = HVLB_CC_B(alpha_max=0.0, alpha_step=0.5)   # orders any DAG


def test_auto_selection_by_processor_count(monkeypatch):
    # the CI matrix pins REPRO_SCHED_BACKEND; this test is about "auto"
    monkeypatch.delenv("REPRO_SCHED_BACKEND", raising=False)
    g3, tg3 = paper_spg(), paper_topology()
    assert Scheduler(tg3).submit(g3, ONE_POINT).backend == "scalar"
    g8, tg8 = _wide(AUTO_VECTOR_MIN_P, 5)
    assert Scheduler(tg8).submit(g8, ONE_POINT).backend == "vector"
    # per-call override beats the session default
    assert Scheduler(tg8, backend="scalar").submit(
        g8, ONE_POINT, backend="vector").backend == "vector"
    # reference engine has no numeric backend
    assert Scheduler(tg3, engine="reference").submit(
        g3, ONE_POINT).backend is None


def test_env_var_overrides_default_backend(monkeypatch):
    g, tg = paper_spg(), paper_topology()
    monkeypatch.setenv("REPRO_SCHED_BACKEND", "vector")
    plan = Scheduler(tg).submit(g, ONE_POINT)
    assert plan.backend == "vector"
    # explicit arguments still win over the environment
    assert Scheduler(tg, backend="scalar").submit(
        g, ONE_POINT).backend == "scalar"


def test_unknown_backend_rejected():
    g, tg = paper_spg(), paper_topology()
    with pytest.raises(ValueError, match="unknown backend"):
        Scheduler(tg, backend="cuda").submit(g, HSV_CC())


def _link_reuse_topology(P):
    loops = {(a, b): [tuple(f"l{a}" for _ in range(2))]
             for a in range(P) for b in range(a + 1, P)}
    return Topology([f"p{i}" for i in range(P)], np.ones(P),
                    {f"l{i}": 1.0 for i in range(P)}, loops)


def test_link_repeating_route_falls_back_to_scalar():
    """A route visiting a link twice is out of the vector backend's
    contract: auto falls back to scalar, explicit vector refuses — at
    resolve time and (defensively) at construction."""
    P = AUTO_VECTOR_MIN_P
    tg = _link_reuse_topology(P)
    assert resolve_backend_name("auto", P, tg) == "scalar"
    with pytest.raises(BackendCompatError, match="scalar"):
        resolve_backend_name("vector", P, tg)
    g = random_spg(10, np.random.default_rng(0), ccr=1.0, tg=tg)
    inst = CompiledInstance(g, tg)
    with pytest.raises(BackendCompatError, match="twice"):
        VectorBackend(inst)


def test_incompatible_backend_rejected_before_session_state():
    """An explicit vector request on a link-reuse topology fails at
    resolve time, inside submit(), *before* any session state exists:
    the plan/trace caches must not end up keyed for a plan that never
    materialized, and the session keeps working with a valid backend."""
    P = AUTO_VECTOR_MIN_P
    tg = _link_reuse_topology(P)
    g = random_spg(10, np.random.default_rng(0), ccr=1.0, tg=tg)
    sched = Scheduler(tg)
    with pytest.raises(BackendCompatError, match="use backend='scalar'"):
        sched.submit(g, HSV_CC(), backend="vector")
    assert sched._sessions == {}            # no half-built graph session
    with pytest.raises(BackendCompatError):
        Scheduler(tg, backend="vector").submit(g, HSV_CC())
    # a failed per-call override leaves the session fully usable and its
    # caches coherent: the scalar plan is fresh, not a stale leftover
    plan = sched.submit(g, HSV_CC(), backend="scalar")
    sess = sched._sessions[id(g)]
    assert set(sess.plans) == {(HSV_CC(), "scalar", DEFAULT_BATCH_MAX)}
    assert plan.backend == "scalar"
    assert plan.batch == DEFAULT_BATCH_MAX


# ------------------------------------------------ pallas (three-way)
PALLAS_POLICIES = [
    HSV_CC(),
    HVLB_CC_A(alpha_max=1.0, alpha_step=0.25, period=150.0),
    HVLB_CC_IC(alpha_max=1.0, alpha_step=0.25, period=150.0),
]


def assert_decisions_identical(a, b):
    """Decision identity (the pallas contract): same winner tuples —
    processor assignments, message routes, replay-relevant structure —
    with start/finish/intervals equal within float tolerance."""
    assert np.array_equal(a.proc, b.proc)
    np.testing.assert_allclose(a.start, b.start, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(a.finish, b.finish, rtol=1e-9, atol=1e-9)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route
        assert (ma.src_proc, ma.dst_proc) == (mb.src_proc, mb.dst_proc)
        np.testing.assert_allclose(np.array([iv[1:] for iv in ma.intervals]),
                                   np.array([iv[1:] for iv in mb.intervals]),
                                   rtol=1e-9, atol=1e-9)
        assert [iv[0] for iv in ma.intervals] == \
            [iv[0] for iv in mb.intervals]


@pytest.mark.parametrize("policy", PALLAS_POLICIES,
                         ids=lambda p: type(p).__name__)
def test_paper_example_three_way(policy):
    """scalar / vector / pallas plans are decision-identical on the
    worked example for every policy class (multi-route topology, CTML
    quantization, IC holes + precision)."""
    pytest.importorskip("jax")
    g, tg = paper_spg(), paper_topology()
    plans = {b: Scheduler(tg, backend=b).submit(g, policy)
             for b in ("scalar", "vector", "pallas")}
    assert plans["pallas"].backend == "pallas"
    for b in ("vector", "pallas"):
        pa, pb = plans["scalar"], plans[b]
        assert_decisions_identical(pa.schedule, pb.schedule)
        assert pa.period == pb.period
        if pa.sweep is not None:
            assert np.array_equal(pa.sweep.alphas, pb.sweep.alphas)
            np.testing.assert_allclose(pa.sweep.makespans,
                                       pb.sweep.makespans, rtol=1e-9)
            assert pa.sweep.best_alpha == pb.sweep.best_alpha
        if pa.holes is not None:
            assert set(pa.holes) == set(pb.holes)
            for t, h in pa.holes.items():
                if np.isinf(h):
                    assert np.isinf(pb.holes[t])
                else:
                    assert pb.holes[t] == pytest.approx(h, rel=1e-9)
                for lam in (0.5, 2.0):
                    assert pb.precision(t, lam) == \
                        pytest.approx(pa.precision(t, lam), rel=1e-9)


@pytest.mark.parametrize("seed", range(0, 200, 29))
def test_three_way_equivalence_random(seed):
    """Corpus slice: single passes and crossing bounds are decision-
    identical across all three backends sharing one compiled instance
    (the bound is compared exactly — the pallas interpret path performs
    the same f64 arithmetic, and the crossing code is shared)."""
    pytest.importorskip("jax")
    g, tg = _case(seed)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 0.85):
        s = inst.schedule(q, alpha=alpha, backend="scalar")
        v = inst.schedule(q, alpha=alpha, backend="vector")
        p = inst.schedule(q, alpha=alpha, backend="pallas")
        assert_identical(s, v)
        assert_decisions_identical(s, p)
        sb, bs = inst.schedule_with_bound(q, alpha, backend="scalar")
        pb, bp = inst.schedule_with_bound(q, alpha, backend="pallas")
        assert_decisions_identical(sb, pb)
        assert bs == pytest.approx(bp, rel=1e-9)


def test_three_way_wide_topology():
    """P = 8 single-route topology (where auto picks vector): the
    device lane batching must agree with both NumPy backends."""
    pytest.importorskip("jax")
    g, tg = _wide(8, 3)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 1.2):
        s = inst.schedule(q, alpha=alpha, backend="scalar")
        assert_identical(s, inst.schedule(q, alpha=alpha, backend="vector"))
        assert_decisions_identical(
            s, inst.schedule(q, alpha=alpha, backend="pallas"))


def test_update_replay_three_way():
    """update() replays decision-identically under pallas: same suffix
    start, same replay counters as scalar/vector."""
    pytest.importorskip("jax")
    rng = np.random.default_rng(2)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    plans = {}
    for backend in ("scalar", "pallas"):
        sched = Scheduler(tg, policy=policy, backend=backend)
        plan = sched.submit(g)
        task = int(np.argmax(plan.schedule.start))
        plans[backend] = sched.update(task_rates={task: 1.5})
    ua, ub = plans["scalar"], plans["pallas"]
    assert_decisions_identical(ua.schedule, ub.schedule)
    assert dataclasses.asdict(ua.replay) == dataclasses.asdict(ub.replay)


@pytest.mark.parametrize("record,resume", [("pallas", "scalar"),
                                           ("scalar", "pallas")])
def test_pallas_traces_portable(record, resume):
    """A trace recorded under pallas replays under scalar and vice
    versa (records hold plain floats; commits are shared scalar code)."""
    pytest.importorskip("jax")
    rng = np.random.default_rng(11)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g, backend=record)
    task = int(np.argmax(plan.schedule.start))
    upd = sched.update(task_rates={task: 0.8}, backend=resume)
    assert upd.backend == resume
    assert upd.replay.decisions_replayed > 0     # the resume actually ran
    fresh = Scheduler(tg, backend="scalar").submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_decisions_identical(upd.schedule, fresh.schedule)


def test_pallas_selection_end_to_end(monkeypatch):
    """backend="pallas" threads through every selection path — session
    default, per-call override, env var — and auto never picks it."""
    pytest.importorskip("jax")
    g, tg = paper_spg(), paper_topology()
    assert Scheduler(tg, backend="pallas").submit(
        g, ONE_POINT).backend == "pallas"
    assert Scheduler(tg).submit(
        g, ONE_POINT, backend="pallas").backend == "pallas"
    monkeypatch.setenv("REPRO_SCHED_BACKEND", "pallas")
    assert Scheduler(tg).submit(g, ONE_POINT).backend == "pallas"
    monkeypatch.delenv("REPRO_SCHED_BACKEND")
    g8, tg8 = _wide(AUTO_VECTOR_MIN_P, 5)
    assert Scheduler(tg8).submit(g8, ONE_POINT).backend == "vector"


def test_paper_example_batched_waves(monkeypatch):
    """The paper queue decomposes into multi-task level waves: batch
    grouping (trace batch ids) is identical across backends, at least
    one wave has size > 1, and the per-wave pallas path pays exactly
    one kernel launch and one host round-trip per wave — O(levels), not
    O(decisions) — while the default scan path folds the whole plan
    into ONE launch / ONE round-trip (DESIGN.md §5)."""
    pytest.importorskip("jax")
    from collections import Counter

    g, tg = paper_spg(), paper_topology()
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    traces = {}
    for b in ("scalar", "vector", "pallas"):
        _, _, traces[b] = inst.schedule_traced(q, alpha=1.06, backend=b)
    bids = [rec[7] for rec in traces["scalar"].records]
    assert bids == [rec[7] for rec in traces["vector"].records]
    assert bids == [rec[7] for rec in traces["pallas"].records]
    counts = Counter(bids)
    assert max(counts.values()) > 1          # a wave of size > 1 ran
    n_waves = len(counts)
    assert n_waves < g.n                     # strictly fewer than decisions
    be = inst.backend_instance("pallas")
    l0, r0 = be.n_launches, be.n_roundtrips
    inst.schedule(q, alpha=1.06, backend="pallas")
    assert be.n_launches - l0 == 1           # whole plan, one dispatch
    assert be.n_roundtrips - r0 == 1
    monkeypatch.setenv("REPRO_PALLAS_SCAN", "0")
    l0, r0 = be.n_launches, be.n_roundtrips
    inst.schedule(q, alpha=1.06, backend="pallas")
    assert be.n_launches - l0 == n_waves     # per-wave fallback
    assert be.n_roundtrips - r0 == n_waves


def test_pallas_supports_link_reuse_routes():
    """Masked per-hop rows walk hops sequentially, so pallas accepts
    topologies whose routes revisit a link (vector refuses them)."""
    pytest.importorskip("jax")
    P = 3
    tg = _link_reuse_topology(P)
    g = random_spg(10, np.random.default_rng(0), ccr=1.0, tg=tg)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    s = inst.schedule(q, alpha=0.5, backend="scalar")
    p = inst.schedule(q, alpha=0.5, backend="pallas")
    assert_decisions_identical(s, p)