"""Scalar vs vector candidate-evaluation backends, bit for bit.

The vector backend re-expresses the engine's per-processor candidate
loop as (P,)-batch array ops, reassociating only exact operations
(IEEE max), so its schedules — start/finish floats, message routes,
per-link intervals, alpha-sweep curves, crossing bounds, IC holes, and
decision-replay counters — must equal the scalar backend's exactly.
No tolerance anywhere in this file.

Covered: the paper worked example (multi-route topology, CTML
quantization), the 200-graph mixed-config corpus, wide single-route
topologies (P = 8, 16 — where "auto" actually picks vector), all four
policies including HVLB_CC_IC schedule holes / precision, and
``Scheduler.update`` trace replay across backends (traces are
backend-portable).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, HVLB_CC_IC,
                        CompiledInstance, Scheduler, paper_spg,
                        paper_topology, random_spg, resolve_backend_name)
from repro.core.backends import AUTO_VECTOR_MIN_P, BackendCompatError
from repro.core.backends.vector import VectorBackend
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.topology import Topology, fully_switched_topology

RATE_PATTERNS = [(1.0, 0.67, 0.83), (0.83, 0.67, 1.0), (0.67, 0.83, 1.0)]

POLICIES = [
    HSV_CC(),
    HVLB_CC_A(alpha_max=1.0, alpha_step=0.25, period=150.0),
    HVLB_CC_B(alpha_max=1.0, alpha_step=0.25, period=150.0),
    HVLB_CC_IC(alpha_max=1.0, alpha_step=0.25, period=150.0),
]


def assert_identical(a, b):
    assert np.array_equal(a.proc, b.proc)
    assert np.array_equal(a.start, b.start)        # exact, no tolerance
    assert np.array_equal(a.finish, b.finish)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route
        assert ma.intervals == mb.intervals        # exact floats
        assert (ma.src_proc, ma.dst_proc) == (mb.src_proc, mb.dst_proc)


def assert_plans_identical(pa, pb):
    assert_identical(pa.schedule, pb.schedule)
    assert pa.period == pb.period
    if pa.sweep is not None:
        assert np.array_equal(pa.sweep.alphas, pb.sweep.alphas)
        assert np.array_equal(pa.sweep.makespans, pb.sweep.makespans)
        assert pa.sweep.best_alpha == pb.sweep.best_alpha
    if pa.holes is not None:
        assert pa.holes == pb.holes                # exact, inf included


def _case(seed: int):
    """Same mixed-config generator as tests/test_engine_equivalence.py."""
    rng = np.random.default_rng(seed)
    rates = RATE_PATTERNS[seed % 3]
    tg = paper_topology(rates=rates)
    ccr = [0.1, 1.0, 10.0][(seed // 3) % 3]
    constrained = (seed // 9) % 2 == 0
    n = int(rng.integers(8, 31))
    g = random_spg(n, rng, ccr=ccr, tg=tg, outdeg_constraint=constrained)
    return g, tg


def _wide(P: int, seed: int, n: int = 28):
    rng = np.random.default_rng(seed)
    tg = fully_switched_topology(P, rates=rng.uniform(0.6, 1.2, size=P),
                                 link_speeds=rng.uniform(0.5, 3.0, size=P))
    g = random_spg(n, rng, ccr=1.0, tg=tg, max_in=3, max_out=6)
    return g, tg


# ---------------------------------------------------------------- paper
@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
def test_paper_example_policies_backend_identical(policy):
    g, tg = paper_spg(), paper_topology()
    pa = Scheduler(tg, backend="scalar").submit(g, policy)
    pb = Scheduler(tg, backend="vector").submit(g, policy)
    assert pa.backend == "scalar" and pb.backend == "vector"
    assert_plans_identical(pa, pb)
    if isinstance(policy, HVLB_CC_IC):
        # unbounded exit holes and degradation curves match exactly
        assert any(np.isinf(h) for h in pa.holes.values())
        for t in pa.holes:
            for lam in (0.5, 2.0, 100.0):
                assert pa.precision(t, lam) == pb.precision(t, lam)


# ------------------------------------------------------------- corpus
@pytest.mark.parametrize("seed", range(200))
def test_backend_equivalence_random(seed):
    """Bit-identical single passes and crossing bounds on the 200-graph
    corpus (paper-style multi-route topology, both backends sharing one
    compiled instance)."""
    g, tg = _case(seed)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 0.85):
        s = inst.schedule(q, alpha=alpha, backend="scalar")
        v = inst.schedule(q, alpha=alpha, backend="vector")
        assert_identical(s, v)
        sb, bs = inst.schedule_with_bound(q, alpha, backend="scalar")
        vb, bv = inst.schedule_with_bound(q, alpha, backend="vector")
        assert_identical(sb, vb)
        assert bs == bv                            # exact bound float


@pytest.mark.parametrize("seed", range(0, 200, 13))
def test_policy_equivalence_random(seed):
    """All four policies produce identical plans under both backends on a
    corpus slice (sweeps, best schedules, IC holes).  Where a policy's
    HPRV_A queue cannot order an unconstrained graph (the Section-3.2
    failure mode), both backends must fail the same way."""
    from repro.core import SchedulingFailure

    g, tg = _case(seed)
    for policy in POLICIES:
        try:
            pa = Scheduler(tg, backend="scalar").submit(g, policy)
        except SchedulingFailure:
            with pytest.raises(SchedulingFailure):
                Scheduler(tg, backend="vector").submit(g, policy)
            continue
        pb = Scheduler(tg, backend="vector").submit(g, policy)
        assert_plans_identical(pa, pb)


@pytest.mark.parametrize("P", [8, 16])
@pytest.mark.parametrize("seed", [3, 17])
def test_backend_equivalence_wide_topology(P, seed):
    """Equivalence where auto-selection actually picks vector."""
    g, tg = _wide(P, seed)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 1.2):
        assert_identical(inst.schedule(q, alpha=alpha, backend="scalar"),
                         inst.schedule(q, alpha=alpha, backend="vector"))
        sb, bs = inst.schedule_with_bound(q, alpha, backend="scalar")
        vb, bv = inst.schedule_with_bound(q, alpha, backend="vector")
        assert_identical(sb, vb)
        assert bs == bv
    pa = Scheduler(tg, backend="scalar").submit(
        g, HVLB_CC_B(alpha_max=1.0, alpha_step=0.25))
    pb = Scheduler(tg, backend="vector").submit(
        g, HVLB_CC_B(alpha_max=1.0, alpha_step=0.25))
    assert_plans_identical(pa, pb)


# ------------------------------------------------------- update replay
@pytest.mark.parametrize("seed,factor", [(0, 0.8), (2, 1.5), (5, 0.7)])
def test_update_replay_backend_identical(seed, factor):
    """update() replays identically under both backends: same suffix
    start, same replay counters, bit-identical plans."""
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    plans = {}
    for backend in ("scalar", "vector"):
        sched = Scheduler(tg, policy=policy, backend=backend)
        plan = sched.submit(g)
        task = int(np.argmax(plan.schedule.start))
        plans[backend] = sched.update(task_rates={task: factor})
    ua, ub = plans["scalar"], plans["vector"]
    assert_plans_identical(ua, ub)
    assert dataclasses.asdict(ua.replay) == dataclasses.asdict(ub.replay)


def test_update_resumes_trace_recorded_by_other_backend():
    """Traces are backend-portable: a trace recorded under scalar replays
    bit-identically when the update runs under vector (and vice versa)."""
    rng = np.random.default_rng(11)
    tg = paper_topology()
    g = random_spg(40, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g, backend="scalar")
    task = int(np.argmax(plan.schedule.start))
    upd = sched.update(task_rates={task: 0.8}, backend="vector")
    assert upd.backend == "vector"
    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_identical(upd.schedule, fresh.schedule)


# ------------------------------------------------------- auto-selection
ONE_POINT = HVLB_CC_B(alpha_max=0.0, alpha_step=0.5)   # orders any DAG


def test_auto_selection_by_processor_count(monkeypatch):
    # the CI matrix pins REPRO_SCHED_BACKEND; this test is about "auto"
    monkeypatch.delenv("REPRO_SCHED_BACKEND", raising=False)
    g3, tg3 = paper_spg(), paper_topology()
    assert Scheduler(tg3).submit(g3, ONE_POINT).backend == "scalar"
    g8, tg8 = _wide(AUTO_VECTOR_MIN_P, 5)
    assert Scheduler(tg8).submit(g8, ONE_POINT).backend == "vector"
    # per-call override beats the session default
    assert Scheduler(tg8, backend="scalar").submit(
        g8, ONE_POINT, backend="vector").backend == "vector"
    # reference engine has no numeric backend
    assert Scheduler(tg3, engine="reference").submit(
        g3, ONE_POINT).backend is None


def test_env_var_overrides_default_backend(monkeypatch):
    g, tg = paper_spg(), paper_topology()
    monkeypatch.setenv("REPRO_SCHED_BACKEND", "vector")
    plan = Scheduler(tg).submit(g, ONE_POINT)
    assert plan.backend == "vector"
    # explicit arguments still win over the environment
    assert Scheduler(tg, backend="scalar").submit(
        g, ONE_POINT).backend == "scalar"


def test_unknown_backend_rejected():
    g, tg = paper_spg(), paper_topology()
    with pytest.raises(ValueError, match="unknown backend"):
        Scheduler(tg, backend="pallas").submit(g, HSV_CC())


def test_link_repeating_route_falls_back_to_scalar():
    """A route visiting a link twice is out of the vector backend's
    contract: auto falls back to scalar, explicit vector refuses."""
    P = AUTO_VECTOR_MIN_P
    loops = {(a, b): [tuple(f"l{a}" for _ in range(2))]
             for a in range(P) for b in range(a + 1, P)}
    tg = Topology([f"p{i}" for i in range(P)], np.ones(P),
                  {f"l{i}": 1.0 for i in range(P)}, loops)
    assert resolve_backend_name("auto", P, tg) == "scalar"
    g = random_spg(10, np.random.default_rng(0), ccr=1.0, tg=tg)
    inst = CompiledInstance(g, tg)
    with pytest.raises(BackendCompatError, match="twice"):
        inst.backend_instance("vector")