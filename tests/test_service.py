"""Service-layer unit + e2e tests (DESIGN.md §8).

Covers the shard ring, the coalescer, the wire protocol, the async
service itself (coalescing, error protocol, LRU eviction/rebuild), and
a TCP round-trip through ``python -m repro.service``'s server.  All
asyncio usage is ``asyncio.run`` from sync tests — no pytest-asyncio.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.core import (HVLB_CC_B, Scheduler, fully_switched_topology,
                        paper_topology, random_spg, schedule_violations)
from repro.core.graph import SPG
from repro.service import (COALESCIBLE, Batch, HashRing, ProtocolError,
                           Request, Response, SchedulerService, coalesce,
                           decode_request, decode_response, encode_request,
                           encode_response, shard_key, spg_from_json,
                           spg_to_json, stable_hash)
from repro.service.__main__ import serve


def _tg(P=4):
    rates = [1.0, 1.1, 0.9, 1.2, 0.8, 1.0, 1.05, 0.95][:P]
    speeds = [1.0, 1.5, 0.9, 1.2, 1.1, 1.3, 1.0, 2.0][:P]
    return fully_switched_topology(P, rates=rates, link_speeds=speeds)


def _graphs(tg, k=3, n=12, seed=0):
    rng = np.random.default_rng(seed)
    gs = [random_spg(n, rng, tg=tg, outdeg_constraint=True)
          for _ in range(k)]
    for i, g in enumerate(gs):
        g.name = f"g{i}"
    return gs


_POLICY = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)


# ------------------------------------------------------------ sharding
class TestHashRing:
    def test_stable_hash_is_process_independent(self):
        # pinned value: must never depend on PYTHONHASHSEED or platform
        assert stable_hash("tenantA") == stable_hash("tenantA")
        assert stable_hash("tenantA") != stable_hash("tenantB")
        assert stable_hash("") == 0xe3b0c44298fc1c14

    def test_lookup_deterministic_and_total(self):
        ring = HashRing([f"w{i}" for i in range(4)])
        keys = [f"tenant{i}" for i in range(200)]
        owners = [ring.lookup(k) for k in keys]
        assert owners == [ring.lookup(k) for k in keys]
        # every shard serves someone (64 vnodes/shard spreads well)
        assert set(owners) == {f"w{i}" for i in range(4)}

    def test_resize_moves_few_keys(self):
        keys = [f"tenant{i}" for i in range(400)]
        r4 = HashRing([f"w{i}" for i in range(4)])
        r5 = HashRing([f"w{i}" for i in range(5)])
        moved = sum(r4.lookup(k) != r5.lookup(k) for k in keys)
        # consistent hashing: roughly 1/5 move, certainly not most
        assert moved < len(keys) // 2

    def test_shard_key_contract(self):
        assert shard_key("carA") == "carA"
        assert shard_key("carA", "3p-3l") == "carA@3p-3l"

    def test_validation(self):
        with pytest.raises(ValueError):
            HashRing([])
        with pytest.raises(ValueError):
            HashRing(["a", "a"])
        with pytest.raises(ValueError):
            HashRing(["a"], replicas=0)


# ---------------------------------------------------------- coalescing
class TestCoalesce:
    def test_adjacent_runs_merge(self):
        items = ["r1", "r2", "u1", "u2", "u3", "p1", "r3"]
        kinds = {"r": "register", "u": "update", "p": "plan"}
        out = coalesce(items, lambda s: kinds[s[0]])
        assert [(b.kind, b.items) for b in out] == [
            ("register", ["r1", "r2"]),
            ("update", ["u1", "u2", "u3"]),
            ("plan", ["p1"]),
            ("register", ["r3"]),
        ]

    def test_fault_ops_are_barriers(self):
        items = ["u1", "f1", "u2", "f2", "f3", "u3"]
        out = coalesce(items, lambda s: "mark_failed" if s[0] == "f"
                       else "update")
        assert [(b.kind, len(b)) for b in out] == [
            ("update", 1), ("mark_failed", 1), ("update", 1),
            ("mark_failed", 1), ("mark_failed", 1), ("update", 1)]
        assert "mark_failed" not in COALESCIBLE

    def test_nothing_reordered_or_dropped(self):
        rng = np.random.default_rng(3)
        kinds = ["register", "update", "plan", "mark_failed", "restore"]
        items = [(kinds[int(rng.integers(len(kinds)))], i)
                 for i in range(60)]
        out = coalesce(items, lambda it: it[0])
        assert [it for b in out for it in b.items] == items


# ------------------------------------------------------------ protocol
class TestProtocol:
    def test_request_roundtrip(self):
        req = Request(7, "update", "carA",
                      {"graph": "g0", "task_rates": {"3": 1.5}})
        got = decode_request(encode_request(req))
        assert got == req

    def test_response_roundtrip(self):
        ok = Response.success(1, {"makespan": 12.25})
        err = Response.failure(2, "infeasible", "no placement")
        assert decode_response(encode_response(ok)) == ok
        assert decode_response(encode_response(err)) == err

    def test_spg_roundtrip_bit_exact(self):
        tg = _tg()
        g = _graphs(tg, k=1, seed=5)[0]
        g2 = spg_from_json(spg_to_json(g))
        assert g2.n == g.n and g2.edges == g.edges and g2.name == g.name
        assert np.array_equal(g2.weights, g.weights)   # exact round-trip
        assert g2.tpl == g.tpl
        assert g2.tpl_proportional_ccr == g.tpl_proportional_ccr

    def test_malformed_lines_raise(self):
        with pytest.raises(ProtocolError):
            decode_request(b"not json\n")
        with pytest.raises(ProtocolError):
            decode_request(b'{"op": "plan"}\n')          # missing id/tenant
        with pytest.raises(ProtocolError):
            decode_request(b'{"id": 1, "op": "nope", "tenant": "t"}\n')
        with pytest.raises(ProtocolError):
            decode_response(b'{"id": 1}\n')
        with pytest.raises(ProtocolError):
            spg_from_json({"n": 2})


# ------------------------------------------------------------- service
def _run(coro):
    return asyncio.run(coro)


class TestService:
    def test_register_burst_coalesces_to_one_replan(self):
        tg = _tg()
        gs = _graphs(tg)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            futs = [asyncio.ensure_future(c.register(g, name=g.name))
                    for g in gs]
            resps = await asyncio.gather(*futs)
            return svc, resps

        svc, resps = _run(main())
        assert all(r.ok for r in resps)
        assert svc.stats.replans == 1          # one submit_many, not 3
        assert svc.stats.coalesced_events == 3
        # per-graph views slice the one fleet plan
        fleet = svc._tenants["carA"].fleet
        for k, r in enumerate(resps):
            sub = fleet.subschedule(k)
            assert r.result["graph"] == f"g{k}"
            assert r.result["proc"] == [int(x) for x in sub.proc]
            assert r.result["start"] == [float(x) for x in sub.start]
            assert r.result["makespan"] == float(fleet.makespan)

    def test_update_burst_folds_into_one_replay(self):
        tg = _tg()
        gs = _graphs(tg)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            await asyncio.gather(*[
                asyncio.ensure_future(c.register(g, name=g.name))
                for g in gs])
            futs = [
                asyncio.ensure_future(c.update(task_rates={1: 1.5},
                                               graph="g0")),
                asyncio.ensure_future(c.update(task_rates={3: 0.8},
                                               graph="g1")),
                asyncio.ensure_future(c.update(
                    link_speed={tg.all_links()[0]: 0.5})),
            ]
            return svc, await asyncio.gather(*futs)

        svc, resps = _run(main())
        assert all(r.ok for r in resps)
        assert svc.stats.replans == 2          # register burst + update burst
        assert resps[0].result["replay"]["coalesced"] == 3

    def test_responses_identical_with_and_without_coalescing(self):
        tg = _tg()
        gs = _graphs(tg)

        async def drive(coalesce):
            svc = SchedulerService(tg, _POLICY, coalesce=coalesce)
            c = svc.client("carA")
            await asyncio.gather(*[
                asyncio.ensure_future(c.register(g, name=g.name))
                for g in gs])
            await asyncio.gather(*[
                asyncio.ensure_future(c.update(task_rates={2: 1.3},
                                               graph="g0")),
                asyncio.ensure_future(c.update(task_rates={4: 0.9},
                                               graph="g2")),
            ])
            final = [(await c.plan(graph=g.name)).result for g in gs]
            return svc, final

        svc_on, fin_on = _run(drive(True))
        svc_off, fin_off = _run(drive(False))
        assert fin_on == fin_off               # bit-identical views
        assert svc_off.stats.replans > svc_on.stats.replans

    def test_matches_direct_scheduler_and_validates(self):
        tg = _tg()
        gs = _graphs(tg)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            await asyncio.gather(*[
                asyncio.ensure_future(c.register(g, name=g.name))
                for g in gs])
            await c.update(task_rates={1: 1.4}, graph="g1")
            await c.mark_failed(proc=2)
            return svc, (await c.plan(graph="g0")).result

        svc, view = _run(main())
        t = svc._tenants["carA"]
        fresh = Scheduler(
            t.topology,
            policy=dataclasses.replace(_POLICY, period=view["period"]),
            faults=t.fault_records)
        fleet = fresh.submit_many(list(t.graphs.values()))
        assert float(fleet.makespan) == view["makespan"]
        sub = fleet.subschedule(0)
        assert view["proc"] == [int(x) for x in sub.proc]
        assert view["start"] == [float(x) for x in sub.start]
        assert schedule_violations(fleet.schedule, fresh.faults) == []

    def test_error_protocol(self):
        tg = _tg()
        gs = _graphs(tg, k=2)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            out = {"no_graphs": await c.plan(),
                   "no_graphs_update": await c.update(
                       task_rates={0: 1.5})}
            await c.register(gs[0], name="g0")
            out["dup"] = await c.register(gs[1], name="g0")
            out["unknown_graph"] = await c.update(task_rates={0: 1.5},
                                                  graph="nope")
            out["bad_task"] = await c.update(task_rates={999: 1.5},
                                             graph="g0")
            out["bad_proc"] = await c.mark_failed(proc=99)
            out["bad_op"] = await svc.request("carA", "frobnicate")
            out["still_serving"] = await c.plan(graph="g0")
            return svc, out

        svc, out = _run(main())
        assert out["no_graphs"].error["code"] == "no-graphs"
        assert out["no_graphs_update"].error["code"] == "no-graphs"
        assert out["dup"].error["code"] == "bad-request"
        assert out["unknown_graph"].error["code"] == "bad-request"
        assert out["bad_task"].error["code"] == "bad-request"
        assert out["bad_proc"].error["code"] == "bad-request"
        assert out["bad_op"].error["code"] == "bad-request"
        # failed requests never wedge the tenant
        assert out["still_serving"].ok
        assert svc.stats.errors == 6
        # the duplicate-name register rolled back cleanly
        assert list(svc._tenants["carA"].graphs) == ["g0"]

    def test_infeasible_surfaces_and_restore_heals(self):
        tg = fully_switched_topology(2, rates=[1.0, 1.0],
                                     link_speeds=[1.0, 1.0])
        g = SPG(n=3, edges=[(0, 2), (1, 2)], weights=[4.0, 4.0, 2.0],
                tpl={(0, 2): 2.0, (1, 2): 2.0}, name="join")

        async def main():
            svc = SchedulerService(
                tg, HVLB_CC_B(alpha_max=1.0, alpha_step=1.0))
            c = svc.client("carA")
            r0 = await c.register(g, name="join")
            if len(set(r0.result["proc"][:2])) < 2:
                return None                   # entries co-located
            broken = await c.mark_failed(link="l1")
            stale = await c.plan()            # must NOT serve the old plan
            healed = await c.restore(link="l1")
            after = await c.plan()
            return r0, broken, stale, healed, after

        out = _run(main())
        if out is None:
            pytest.skip("entries co-located; no partition to exercise")
        r0, broken, stale, healed, after = out
        assert broken.error["code"] == "infeasible"
        assert stale.error["code"] == "infeasible"
        assert healed.ok
        assert after.ok
        assert after.result["makespan"] == r0.result["makespan"]

    def test_fault_before_register_seeds_later_plans(self):
        tg = _tg()
        gs = _graphs(tg, k=1)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            pre = await c.mark_failed(proc=3)
            reg = await c.register(gs[0], name="g0")
            return pre, reg

        pre, reg = _run(main())
        assert pre.ok and pre.result["deferred"]
        assert reg.ok
        assert 3 not in reg.result["proc"]    # the fault was honoured
        assert reg.result["faults"]["down_procs"] == [3]

    def test_tenants_shard_across_lanes(self):
        tg = _tg()

        async def main():
            svc = SchedulerService(tg, _POLICY, workers=4)
            lanes = {f"tenant{i}": svc.tenant_lane(f"tenant{i}")
                     for i in range(40)}
            return svc, lanes

        svc, lanes = _run(main())
        assert set(lanes.values()) == {0, 1, 2, 3}
        # pure function of the shard key: stable on re-query
        assert all(svc.tenant_lane(t) == lane
                   for t, lane in lanes.items())

    def test_lru_eviction_rebuilds_bit_identically(self):
        tg = _tg()
        gs = _graphs(tg, k=2)

        async def main():
            svc = SchedulerService(tg, _POLICY, workers=1,
                                   max_tenants_per_worker=1)
            a, b = svc.client("tA"), svc.client("tB")
            await a.register(gs[0], name="g0")
            await a.update(task_rates={2: 1.3}, graph="g0")
            before = (await a.plan(graph="g0")).result
            await b.register(gs[1], name="g1")     # evicts tA's session
            evicted = svc._tenants["tA"].sched is None
            after = (await a.plan(graph="g0")).result
            return svc, before, evicted, after

        svc, before, evicted, after = _run(main())
        assert evicted
        assert svc.stats.evictions >= 1
        assert after == before                 # rebuild is invisible

    def test_task_degrade_matches_update_drift(self):
        # a service-level compute spike IS the update(task_rates=...)
        # drift machinery: the final plan views must be bit-identical
        tg = _tg()
        g = _graphs(tg, k=1)[0]

        async def drive(op):
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            await c.register(g, name="g0")
            if op == "degrade":
                r = await asyncio.wait_for(
                    c.degrade(task=2, factor=1.6), timeout=30)
            else:
                r = await c.update(task_rates={2: 1.6}, graph="g0")
            assert r.ok, r.error
            return (await c.plan(graph="g0")).result

        assert _run(drive("degrade")) == _run(drive("update"))

    def test_task_degrade_before_register_is_structured_error(self):
        # regression: this used to AssertionError inside the flush task
        # (t.fleet is None pre-registration) and strand the client
        tg = _tg()

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            resp = await asyncio.wait_for(
                c.degrade(task=0, factor=2.0), timeout=30)
            still = await asyncio.wait_for(
                c.register(_graphs(tg, k=1)[0], name="g0"), timeout=30)
            return resp, still

        resp, still = _run(main())
        assert resp.error["code"] == "no-graphs"
        assert still.ok                        # the tenant is not wedged

    def test_task_degrade_after_eviction_rebuilds(self):
        # regression: t.fleet is None after an LRU eviction; the spike
        # must transparently rebuild, not AssertionError
        tg = _tg()
        gs = _graphs(tg, k=2)

        async def main():
            svc = SchedulerService(tg, _POLICY, workers=1,
                                   max_tenants_per_worker=1)
            a, b = svc.client("tA"), svc.client("tB")
            await a.register(gs[0], name="g0")
            await b.register(gs[1], name="g1")     # evicts tA's session
            assert svc._tenants["tA"].sched is None
            r = await asyncio.wait_for(
                a.degrade(task=3, factor=1.4), timeout=30)
            return r, (await a.plan(graph="g0")).result

        r, view = _run(main())
        assert r.ok, r.error
        # the rebuilt + degraded plan matches a direct session doing the
        # same spike with no eviction in between
        fresh = Scheduler(tg, policy=_POLICY)
        fresh.submit_many([gs[0]])
        plan = fresh.degrade(task=3, factor=1.4)
        assert view["makespan"] == float(plan.makespan)
        assert view["proc"] == [int(x) for x in plan.schedule.proc]

    def test_task_degrade_after_infeasible_replan(self):
        # regression: after an infeasible replan t.fleet is None while
        # t.sched survives; a task degrade must answer "infeasible",
        # not AssertionError, and a restore must still heal the tenant
        tg = fully_switched_topology(2, rates=[1.0, 1.0],
                                     link_speeds=[1.0, 1.0])
        g = SPG(n=3, edges=[(0, 2), (1, 2)], weights=[4.0, 4.0, 2.0],
                tpl={(0, 2): 2.0, (1, 2): 2.0}, name="join")

        async def main():
            svc = SchedulerService(
                tg, HVLB_CC_B(alpha_max=1.0, alpha_step=1.0))
            c = svc.client("carA")
            r0 = await c.register(g, name="join")
            if len(set(r0.result["proc"][:2])) < 2:
                return None                   # entries co-located
            broken = await c.mark_failed(link="l1")
            spike = await asyncio.wait_for(
                c.degrade(task=0, factor=2.0), timeout=30)
            healed = await c.restore(link="l1")
            return broken, spike, healed

        out = _run(main())
        if out is None:
            pytest.skip("entries co-located; no partition to exercise")
        broken, spike, healed = out
        assert broken.error["code"] == "infeasible"
        assert spike.error["code"] == "infeasible"
        assert healed.ok

    def test_invalid_item_does_not_poison_coalesced_batch(self):
        # a mixed burst: the invalid update fails alone, the valid ones
        # fold into one replay, and the final state is bit-identical to
        # uncoalesced per-item processing
        tg = _tg()
        gs = _graphs(tg)

        async def drive(coalesce):
            svc = SchedulerService(tg, _POLICY, coalesce=coalesce)
            c = svc.client("carA")
            await asyncio.gather(*[
                asyncio.ensure_future(c.register(g, name=g.name))
                for g in gs])
            resps = await asyncio.gather(
                asyncio.ensure_future(c.update(task_rates={1: 1.3},
                                               graph="g0")),
                asyncio.ensure_future(c.update(task_rates={999: 1.5},
                                               graph="g0")),
                asyncio.ensure_future(c.update(task_rates={2: 0.9},
                                               graph="g1")),
            )
            final = [(await c.plan(graph=g.name)).result for g in gs]
            return svc, resps, final

        svc_on, on, fin_on = _run(drive(True))
        svc_off, off, fin_off = _run(drive(False))
        for resps in (on, off):
            assert resps[0].ok and resps[2].ok
            assert resps[1].error["code"] == "bad-request"
        assert fin_on == fin_off               # bit-identical end state
        # the two valid events still folded into ONE suffix replay
        assert on[0].result["replay"]["coalesced"] == 2
        assert svc_on.stats.replans < svc_off.stats.replans

    def test_register_burst_with_duplicate_keeps_valid_items(self):
        tg = _tg()
        gs = _graphs(tg, k=3)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            resps = await asyncio.gather(
                asyncio.ensure_future(c.register(gs[0], name="a")),
                asyncio.ensure_future(c.register(gs[1], name="a")),
                asyncio.ensure_future(c.register(gs[2], name="b")),
            )
            return svc, resps

        svc, resps = _run(main())
        assert resps[0].ok and resps[2].ok
        assert resps[1].error["code"] == "bad-request"
        assert list(svc._tenants["carA"].graphs) == ["a", "b"]
        assert svc.stats.replans == 1          # one replan of the valid pair

    def test_unknown_graph_plan_does_not_poison_batch_mates(self):
        tg = _tg()
        gs = _graphs(tg, k=1)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            c = svc.client("carA")
            await c.register(gs[0], name="g0")
            return await asyncio.gather(
                asyncio.ensure_future(c.plan(graph="g0")),
                asyncio.ensure_future(c.plan(graph="nope")),
                asyncio.ensure_future(c.plan()),
            )

        good, bad, fleet = _run(main())
        assert good.ok and fleet.ok
        assert bad.error["code"] == "bad-request"

    def test_stats_op(self):
        tg = _tg()
        gs = _graphs(tg, k=1)

        async def main():
            svc = SchedulerService(tg, _POLICY)
            await svc.client("carA").register(gs[0], name="g0")
            return await svc.request("carA", "stats")

        resp = _run(main())
        assert resp.ok
        assert resp.result["replans"] == 1
        assert resp.result["requests"] == 1


# ----------------------------------------------------------------- TCP
class TestTcpServer:
    def test_pipelined_roundtrip(self):
        tg = _tg()
        g = _graphs(tg, k=1, seed=7)[0]

        async def main():
            svc = SchedulerService(tg, _POLICY, workers=2)
            try:
                server = await serve(svc, "127.0.0.1", 0)
            except OSError as e:               # sandboxed CI: no sockets
                return ("skip", str(e))
            host, port = server.sockets[0].getsockname()[:2]
            reader, writer = await asyncio.open_connection(host, port)
            reqs = [
                Request(1, "register", "carA",
                        {"name": "g0", "graph": spg_to_json(g)}),
                Request(2, "update", "carA",
                        {"graph": "g0", "task_rates": {"2": 1.4}}),
                Request(3, "plan", "carA", {"graph": "g0"}),
                Request(4, "mark_failed", "carA", {"proc": 99}),
                Request(5, "stats", "carA", {}),
            ]
            for r in reqs:                     # pipelined burst
                writer.write(encode_request(r))
            await writer.drain()
            got = {}
            for _ in reqs:
                resp = decode_response(await reader.readline())
                got[resp.id] = resp
            writer.close()
            await writer.wait_closed()
            server.close()
            await server.wait_closed()
            return ("ok", got)

        status, got = _run(main())
        if status == "skip":
            pytest.skip(f"cannot bind a localhost socket: {got}")
        assert got[1].ok and got[2].ok and got[3].ok and got[5].ok
        assert not got[4].ok
        assert got[4].error["code"] == "bad-request"
        # the plan view equals the update's view (same fleet state)
        assert got[3].result["proc"] == got[2].result["proc"]
        assert got[3].result["makespan"] == got[2].result["makespan"]

    def test_reserved_key_collision_gets_error_response(self):
        # a JSON-valid request whose extra key collides with the
        # dispatcher's own parameters must still get a response line —
        # a silent swallow would hang a pipelined client on that id
        tg = _tg()

        async def main():
            svc = SchedulerService(tg, _POLICY)
            try:
                server = await serve(svc, "127.0.0.1", 0)
            except OSError as e:
                return ("skip", str(e))
            host, port = server.sockets[0].getsockname()[:2]
            reader, writer = await asyncio.open_connection(host, port)
            writer.write(
                b'{"id": 6, "op": "plan", "tenant": "carA", "rid": 9}\n')
            await writer.drain()
            resp = decode_response(
                await asyncio.wait_for(reader.readline(), timeout=30))
            writer.close()
            await writer.wait_closed()
            server.close()
            await server.wait_closed()
            return ("ok", resp)

        status, resp = _run(main())
        if status == "skip":
            pytest.skip(f"cannot bind a localhost socket: {resp}")
        assert not resp.ok
        assert resp.id == 6
        assert resp.error["code"] == "internal"

    def test_malformed_line_gets_error_response(self):
        tg = _tg()

        async def main():
            svc = SchedulerService(tg, _POLICY)
            try:
                server = await serve(svc, "127.0.0.1", 0)
            except OSError as e:
                return ("skip", str(e))
            host, port = server.sockets[0].getsockname()[:2]
            reader, writer = await asyncio.open_connection(host, port)
            writer.write(b"this is not json\n")
            await writer.drain()
            resp = decode_response(await reader.readline())
            writer.close()
            await writer.wait_closed()
            server.close()
            await server.wait_closed()
            return ("ok", resp)

        status, resp = _run(main())
        if status == "skip":
            pytest.skip(f"cannot bind a localhost socket: {resp}")
        assert not resp.ok
        assert resp.error["code"] == "bad-request"
