"""Per-architecture smoke tests: reduced same-family configs, one
forward + one train-grad step + one decode step on CPU; shape and
finiteness asserts.  (Full configs are exercised only by the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.model import (abstract_cache, decode_step, forward,
                                init_cache, loss_fn)
from repro.models.params import init_params

B, S = 2, 32


def _batch(cfg, key):
    kt, kl, kv = jax.random.split(key, 3)
    if cfg.embed_inputs:
        batch = {"embeds": jax.random.normal(kv, (B, S, cfg.d_model),
                                             jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab)}
        if cfg.vision_prefix:
            batch["vision_embeds"] = jax.random.normal(
                kv, (B, S // 4, cfg.d_model), jnp.float32) * 0.02
    batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_grad(name):
    cfg = reduced_config(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch)))(params)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("name", sorted(n for n in ARCHS
                                        if ARCHS[n].decoder))
def test_decode_step(name):
    cfg = reduced_config(ARCHS[name])
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, max_seq=S)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
    logits, cache = step(params, cache, tok, pos)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits2, cache = step(params, cache, tok, pos + 1)
    assert bool(jnp.isfinite(logits2).all())
    # cache tree shapes preserved
    for a, b in zip(jax.tree.leaves(abstract_cache(cfg, B, S)),
                    jax.tree.leaves(cache)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = reduced_config(ARCHS["qwen3-8b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    full = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, max_seq=8)
    outs = []
    for t in range(8):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Recurrent SSM decode must match the chunked-scan forward path."""
    cfg = reduced_config(ARCHS["falcon-mamba-7b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)
    full = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, max_seq=8)
    outs = []
    for t in range(8):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-2, atol=5e-2)
