"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.kernel import selective_scan_kernel
from repro.kernels.ssm_scan.ref import selective_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (1, 4, 4, 256, 64),         # MHA
    (2, 8, 2, 256, 64),         # GQA 4:1
    (1, 4, 1, 512, 128),        # MQA, larger S and head dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=128,
                                 block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block_q,block_k", [(128, 128), (128, 256)])
def test_flash_attention_block_shapes(block_q, block_k):
    B, Hq, Hkv, S, d = 1, 2, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, block_q=block_q,
                                 block_k=block_k, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,Di,N", [
    (1, 256, 512, 16),
    (2, 512, 256, 8),
    (1, 256, 1024, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_matches_ref(B, S, Di, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, Di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di), dtype) - 2)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    out = selective_scan_kernel(x, dt, A, Bm, Cm, block_d=min(256, Di),
                                block_s=128, interpret=True)
    ref = selective_scan_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_selective_scan_state_carry_across_seq_blocks():
    """The h carry must flow across grid steps on the sequence axis."""
    B, S, Di, N = 1, 512, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, Di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)) - 2)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    small = selective_scan_kernel(x, dt, A, Bm, Cm, block_d=128,
                                  block_s=64, interpret=True)
    big = selective_scan_kernel(x, dt, A, Bm, Cm, block_d=128,
                                block_s=512, interpret=True)
    np.testing.assert_allclose(np.asarray(small), np.asarray(big),
                               rtol=1e-5, atol=1e-5)
