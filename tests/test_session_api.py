"""The Scheduler session API: submit/update/submit_many semantics.

Bit-identity of the session against the one-shot shims and the readable
reference lives in tests/test_engine_equivalence.py; this file covers the
session-only behaviour — incremental ``update`` (trace-suffix replay,
asserted via the decision-replay counters), ``probe_update``, fleet
``submit_many``, the imprecise-computation policy, the SweepResult array
accessors, and the serving engine's lazy re-planning.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, HVLB_CC_IC, Scheduler,
                        paper_spg, paper_topology, random_spg)
from repro.core.api import _disjoint_union


def assert_same_schedule(a, b):
    assert np.array_equal(a.proc, b.proc)
    assert np.array_equal(a.start, b.start)       # exact, no tolerance
    assert np.array_equal(a.finish, b.finish)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route and ma.intervals == mb.intervals


def _case(seed, n=30):
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(n, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    return g, tg


# ------------------------------------------------------------- update
@pytest.mark.parametrize("seed,factor", [(0, 0.8), (1, 0.8), (2, 1.5),
                                         (3, 0.9), (4, 2.0), (5, 0.7),
                                         (6, 1.2), (7, 0.95)])
def test_update_task_rates_matches_fresh_submit(seed, factor):
    """update() == from-scratch submit of the modified graph (bit-exact),
    re-simulating only a trace suffix."""
    g, tg = _case(seed)
    policy = HVLB_CC_B(alpha_max=2.0, alpha_step=0.25)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g)
    task = int(np.argmax(plan.schedule.start))    # a late task
    upd = sched.update(task_rates={task: factor})

    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_same_schedule(upd.schedule, fresh.schedule)
    np.testing.assert_array_equal(upd.sweep.alphas, fresh.sweep.alphas)
    np.testing.assert_array_equal(upd.sweep.makespans, fresh.sweep.makespans)
    assert upd.sweep.best_alpha == fresh.sweep.best_alpha
    # only a suffix was re-simulated (the counters prove replay happened)
    if upd.replay.suffix_start > 0:
        assert upd.replay.decisions_replayed > 0
        assert upd.replay.decisions_simulated < \
            fresh.replay.decisions_simulated


def test_update_replays_long_prefix_for_local_drift():
    """A sink whose rank influence stays local keeps most of the trace."""
    g, tg = _case(11, n=60)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g)
    sinks = [t for t in range(g.n) if not g.succ[t]]
    task = max(sinks, key=lambda t: sched.probe_update(task_rates={t: 0.9}))
    probed = sched.probe_update(task_rates={task: 0.9})
    upd = sched.update(task_rates={task: 0.9})
    assert upd.replay.suffix_start == probed
    assert upd.replay.decisions_replayed > 0
    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_same_schedule(upd.schedule, fresh.schedule)


def test_update_chain_stays_consistent():
    """Consecutive updates compound on the current graph."""
    g, tg = _case(21)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g)
    u1 = sched.update(task_rates={5: 1.3})
    u2 = sched.update(task_rates={17: 0.6})
    assert u2.graph.weights[5] == pytest.approx(g.weights[5] * 1.3)
    assert u2.graph.weights[17] == pytest.approx(g.weights[17] * 0.6)
    fresh = Scheduler(tg).submit(
        u2.graph, dataclasses.replace(policy, period=plan.period))
    assert_same_schedule(u2.schedule, fresh.schedule)


def test_update_link_speed_matches_fresh_submit():
    """Link drift invalidates everything (LDET changes) but still matches
    a from-scratch submit on the updated topology."""
    g, tg = _case(31)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)
    sched = Scheduler(tg, policy=policy)
    plan = sched.submit(g)
    upd = sched.update(link_speed={"l3": 1.5})
    assert upd.replay.suffix_start == 0
    assert sched.topology.link_speed["l3"] == 1.5
    fresh = Scheduler(sched.topology).submit(
        upd.graph, dataclasses.replace(policy, period=plan.period))
    assert_same_schedule(upd.schedule, fresh.schedule)


def test_update_unknown_link_and_missing_submit_raise():
    g, tg = _case(41)
    sched = Scheduler(tg)
    with pytest.raises(ValueError, match="before any submit"):
        sched.update(task_rates={0: 2.0})
    sched.submit(g)
    with pytest.raises(ValueError, match="unknown links"):
        sched.update(link_speed={"nope": 1.0})


def test_update_noop_returns_cached_plan():
    g, tg = _case(51)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    plan = sched.submit(g)
    again = sched.update(task_rates={3: 1.0})     # factor 1.0 == no drift
    assert again is plan


def test_update_hsv_policy():
    """The single-pass baseline policy replays too (no sweep)."""
    g, tg = _case(61)
    sched = Scheduler(tg, policy=HSV_CC())
    plan = sched.submit(g)
    task = int(np.argmax(plan.schedule.start))
    upd = sched.update(task_rates={task: 0.8})
    fresh = Scheduler(tg, policy=HSV_CC()).submit(upd.graph)
    assert_same_schedule(upd.schedule, fresh.schedule)


def test_update_reference_engine_full_replan():
    """The reference engine has no traces: update falls back to a full
    re-plan but stays output-identical to the compiled path."""
    g, tg = _case(71)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    ref = Scheduler(tg, policy=policy, engine="reference")
    ref_plan = ref.submit(g)
    com = Scheduler(tg, policy=policy)
    com.submit(g)
    task = int(np.argmax(ref_plan.schedule.start))
    ur = ref.update(task_rates={task: 1.4})
    uc = com.update(task_rates={task: 1.4})
    assert ur.replay.suffix_start == 0 and ur.replay.decisions_replayed == 0
    assert_same_schedule(ur.schedule, uc.schedule)


# -------------------------------------------------------- submit_many
def test_submit_many_matches_manual_union_and_slices_validate():
    rng = np.random.default_rng(9)
    tg = paper_topology()
    graphs = [random_spg(int(rng.integers(8, 20)), rng, ccr=1.0, tg=tg,
                         outdeg_constraint=True) for _ in range(5)]
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)
    fleet = Scheduler(tg, policy=policy).submit_many(graphs)
    # one engine pass over the disjoint union, shared link state
    union, offsets = _disjoint_union(graphs, tg)
    manual = Scheduler(tg, policy=policy).submit(union)
    assert fleet.offsets == offsets
    assert_same_schedule(fleet.schedule, manual.schedule)
    for k, g in enumerate(graphs):
        sub = fleet.subschedule(k)
        assert sub.graph is g
        sub.validate()                      # per-graph view is consistent
        np.testing.assert_array_equal(
            sub.proc, fleet.schedule.proc[offsets[k]:offsets[k] + g.n])


def test_submit_many_then_incremental_update():
    """The union session supports drift updates keyed by union node ids."""
    rng = np.random.default_rng(19)
    tg = paper_topology()
    graphs = [random_spg(14, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
              for _ in range(4)]
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, policy=policy)
    fleet = sched.submit_many(graphs)
    node = fleet.offsets[3] + 2
    upd = sched.update(task_rates={node: 0.75})
    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(
            policy, period=sched._last.periods[policy]))
    assert_same_schedule(upd.schedule, fresh.schedule)


def test_submit_many_rejects_mixed_tpl_conventions():
    tg = paper_topology()
    g1 = paper_spg(ccr=1.0)
    g2 = paper_spg(ccr=2.0)
    with pytest.raises(ValueError, match="tpl convention"):
        Scheduler(tg).submit_many([g1, g2])
    with pytest.raises(ValueError, match="at least one graph"):
        Scheduler(tg).submit_many([])


# ----------------------------------------------------- batched update
def test_batched_update_matches_sequential_updates():
    """One update() with k event dicts == k sequential update() calls,
    bit-exactly — the coalescing primitive of the serving layer."""
    g, tg = _case(61)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)
    tr_events = [{3: 1.5}, {7: 0.8, 3: 1.1}, {12: 1.3}]
    ls_events = [{"l1": 0.5}, {"l1": 0.75, "l3": 1.2}]

    seq = Scheduler(tg, policy=policy)
    seq.submit(g)
    for ev in tr_events:
        seq.update(task_rates=ev)
    for ev in ls_events:
        last_seq = seq.update(link_speed=ev)

    bat = Scheduler(tg, policy=policy)
    bat.submit(g)
    folded = bat.update(task_rates=tr_events, link_speed=ls_events)

    assert_same_schedule(folded.schedule, last_seq.schedule)
    np.testing.assert_array_equal(folded.graph.weights,
                                  last_seq.graph.weights)
    assert bat.topology.link_speed == seq.topology.link_speed
    assert folded.replay.coalesced == 5       # 3 task + 2 link events
    assert last_seq.replay.coalesced == 1     # plain updates don't fold


def test_batched_update_factors_compose_sequentially():
    """(w * f1) * f2, never w * (f1 * f2): the float fold order must be
    the sequential one or batched != sequential on real hardware."""
    g, tg = _case(71)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    sched.submit(g)
    plan = sched.update(task_rates=[{5: 1.1}, {5: 1.2}, {5: 0.7}])
    assert plan.graph.weights[5] == ((g.weights[5] * 1.1) * 1.2) * 0.7


def test_batched_update_noop_events_do_not_count():
    g, tg = _case(81)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    plan = sched.submit(g)
    # all-noop batch: cached plan comes back untouched
    again = sched.update(task_rates=[{3: 1.0}, {}])
    assert again is plan
    # noop events inside a real batch don't inflate the fold count
    upd = sched.update(task_rates=[{3: 1.0}, {4: 1.5}])
    assert upd.replay.coalesced == 1


def test_batched_update_fleet_suffix_replay():
    """Batched drift on a submit_many union replays one combined
    suffix and matches the fresh fleet submit."""
    rng = np.random.default_rng(91)
    tg = paper_topology()
    gs = [random_spg(12, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
          for _ in range(3)]
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)
    sched = Scheduler(tg, policy=policy)
    fleet = sched.submit_many(gs)
    off1 = gs[0].n                            # graph 1's union offset
    upd = sched.update(task_rates=[{off1 + 2: 1.4}, {off1 + 5: 0.8}])
    assert upd.replay.coalesced == 2
    fresh = Scheduler(tg).submit(
        upd.graph, dataclasses.replace(policy, period=fleet.period))
    assert_same_schedule(upd.schedule, fresh.schedule)


# ----------------------------------------------------- policies/results
def test_sweepresult_array_accessors():
    g, tg = paper_spg(), paper_topology()
    plan = Scheduler(tg).submit(g, HVLB_CC_A(alpha_max=2.0, alpha_step=0.1,
                                             period=150.0))
    sw = plan.sweep
    assert sw.alphas.shape == sw.makespans.shape == (21,)
    assert sw.alphas[0] == 0.0 and sw.alphas[-1] == pytest.approx(2.0)
    assert sw.makespans.min() == pytest.approx(sw.best.makespan)
    # the deprecated list-of-tuples view still round-trips, with a warning
    from repro.core import deprecation
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match="SweepResult.curve"):
        legacy = sw.curve
    np.testing.assert_array_equal(sw.alphas, [a for a, _ in legacy])
    np.testing.assert_array_equal(sw.makespans, [m for _, m in legacy])


def test_ic_policy_attaches_holes_and_precision():
    g, tg = paper_spg(), paper_topology()
    plan = Scheduler(tg).submit(g, HVLB_CC_IC(alpha_max=3.0, period=150.0))
    assert plan.holes, "IC plan must carry schedule holes"
    # exit tasks with nothing after them report unbounded holes
    unbounded = [t for t, h in plan.holes.items() if np.isinf(h)]
    for t in unbounded:
        assert not g.succ[t]
        assert plan.precision(t, 2.0) == 1.0       # optional part always fits
    # a finite-holed task degrades once demand exceeds the hole
    finite = [t for t, h in plan.holes.items() if np.isfinite(h)]
    assert finite
    t = finite[0]
    assert plan.precision(t, 1.0) == 1.0
    assert 0.0 < plan.precision(t, 100.0) < 1.0
    # non-IC plans refuse the accessor
    b = Scheduler(tg).submit(g, HVLB_CC_B(alpha_max=1.0, period=150.0))
    assert b.holes is None
    with pytest.raises(ValueError, match="HVLB_CC_IC"):
        b.precision(0, 1.5)


def test_policies_are_hashable_cache_keys():
    g, tg = paper_spg(), paper_topology()
    sched = Scheduler(tg)
    p1 = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5, period=150.0)
    p2 = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5, period=150.0)
    plan1 = sched.submit(g, p1)
    plan2 = sched.submit(g, p2)          # equal policy -> cached plan
    assert plan1 is plan2
    assert sched.submit(g, HSV_CC()) is not plan1


def test_scheduler_validates_knobs():
    g, tg = paper_spg(), paper_topology()
    with pytest.raises(ValueError, match="unknown engine"):
        Scheduler(tg, engine="jit")
    with pytest.raises(ValueError, match="unknown sweep"):
        Scheduler(tg).submit(g, HVLB_CC_B(sweep="random"))
    with pytest.raises(ValueError, match="requires"):
        Scheduler(tg, engine="reference").submit(
            g, HVLB_CC_B(sweep="adaptive"))


# --------------------------------------------------- serving integration
def test_dsms_engine_lazy_replan_counts():
    """Regression for the O(Q) replan bug: registering Q queries costs one
    re-plan (on first use), not Q."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced_config
    from repro.models.params import init_params
    from repro.serve import DSMSEngine, Query

    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DSMSEngine(cfg, params, batch_size=2, max_seq=8)
    for k in range(3):
        eng.register(Query(f"q{k}", mandatory=lambda lg: jnp.max(lg, -1)))
    assert eng.replans == 0 and eng.plan is None
    eng.ensure_plan()
    assert eng.replans == 1
    eng.ensure_plan()                       # clean -> no extra replan
    assert eng.replans == 1
    # query operator nodes come from the graph's own mapping
    g = eng._graph
    assert set(eng._query_nodes.values()) == \
        {g.query_ops[qi][0] for qi in range(3)}
    assert all(g.pred[n] for n in eng._query_nodes.values())
    eng.register(Query("late", mandatory=lambda lg: jnp.min(lg, -1)))
    assert eng.replans == 1                 # still lazy
    res = eng.step(np.zeros(2, np.int64))   # first step triggers replan
    assert eng.replans == 2
    assert set(res.query_outputs) == {"q0", "q1", "q2", "late"}


def test_dsms_engine_fault_passthrough_and_precision_report():
    """Graceful IC degradation (DESIGN.md §6): a resource failure replans
    through the session fault path and ``StepResult.precision`` reports
    the per-query loss instead of the engine failing."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced_config
    from repro.models.params import init_params
    from repro.serve import DSMSEngine, Query

    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DSMSEngine(cfg, params, batch_size=2, max_seq=8)
    eng.register(Query("alert", mandatory=lambda lg: jnp.max(lg, -1)))
    eng.register(Query("topk",
                       mandatory=lambda lg: jax.lax.top_k(lg[:, -1], 3),
                       optional=lambda r: r, optional_ratio=0.25))
    res = eng.step(np.zeros(2, np.int64))
    assert res.precision["alert"] == 1.0     # no optional part
    assert res.precision["topk"] == \
        (1.0 if res.precise["topk"] else 1.0 / 1.25)

    replans = eng.replans
    eng.mark_failed(proc=0)                  # ECU dies mid-stream
    assert eng.replans == replans + 1
    assert 0 not in set(np.asarray(eng.plan.proc).tolist())
    assert eng.scheduler.faults.down_procs == (0,)
    res = eng.step(res.tokens)               # still serving
    assert set(res.precision) == {"alert", "topk"}
    assert all(0.0 < v <= 1.0 for v in res.precision.values())

    link = eng.topology.all_links()[0]
    eng.degrade(link=link, factor=2.0)
    assert eng.scheduler.faults.link_factor(link) == 2.0
    eng.restore(proc=0)
    eng.restore(link=link)
    assert eng.scheduler.faults.is_empty
    res = eng.step(res.tokens)
    assert res.precision["alert"] == 1.0
