"""Compiled engine == reference scheduler == session API, bit for bit.

The engine (repro.core.engine.CompiledInstance) must reproduce the readable
``list_schedule`` exactly — same processor assignments, same start/finish
floats, same message routes and per-link intervals — on the paper's worked
example and on hundreds of random TGFF graphs across CCR regimes, rate
patterns, and both out-degree-constraint settings.  No tolerance: the
engine performs the same IEEE operations in the same order.

The session API (``Scheduler.submit``) and the deprecated one-shot shims
(``schedule_hsv_cc`` / ``schedule_hvlb_cc``) are asserted against the same
reference on the same graph corpus: shim == session == reference.
"""
import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, CompiledInstance,
                        Scheduler, paper_spg, paper_topology, random_spg,
                        schedule_hsv_cc, schedule_hvlb_cc)
from repro.core.ranks import (hprv_b, ldet_cc, priority_queue,
                              rank_matrix, rank_matrix_reference)
from repro.core.scheduler import Schedule, list_schedule
from repro.core.topology import fully_switched_topology

# The deprecated shims are exercised *deliberately* (shim == session ==
# reference is part of the contract); their once-per-process
# DeprecationWarning is pinned by tests/test_deprecation.py, so it is
# filtered here — narrowly, by message — to keep the suite clean under
# ``-W error::DeprecationWarning`` (the CI invocation).
pytestmark = pytest.mark.filterwarnings(
    "ignore:schedule_h:DeprecationWarning")

RATE_PATTERNS = [(1.0, 0.67, 0.83), (0.83, 0.67, 1.0), (0.67, 0.83, 1.0)]


def assert_identical(a: Schedule, b: Schedule) -> None:
    assert np.array_equal(a.proc, b.proc)
    assert np.array_equal(a.start, b.start)        # exact, no tolerance
    assert np.array_equal(a.finish, b.finish)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route
        assert ma.intervals == mb.intervals        # exact floats
        assert (ma.src_proc, ma.dst_proc) == (mb.src_proc, mb.dst_proc)


def _case(seed: int):
    """Deterministic mixed-config case generator (~equal coverage of both
    outdeg settings, three CCRs, three rate patterns)."""
    rng = np.random.default_rng(seed)
    rates = RATE_PATTERNS[seed % 3]
    tg = paper_topology(rates=rates)
    ccr = [0.1, 1.0, 10.0][(seed // 3) % 3]
    constrained = (seed // 9) % 2 == 0
    n = int(rng.integers(8, 31))
    g = random_spg(n, rng, ccr=ccr, tg=tg, outdeg_constraint=constrained)
    return g, tg


# ---------------------------------------------------------------- paper
def test_paper_example_hsv_identical():
    g, tg = paper_spg(), paper_topology()
    ref = schedule_hsv_cc(g, tg, engine="reference")
    assert_identical(ref, schedule_hsv_cc(g, tg, engine="compiled"))
    # session == shim == reference
    assert_identical(ref, Scheduler(tg).submit(g, HSV_CC()).schedule)


@pytest.mark.parametrize("variant", ["A", "B"])
def test_paper_example_sweep_identical(variant):
    g, tg = paper_spg(), paper_topology()
    ref = schedule_hvlb_cc(g, tg, variant=variant, alpha_max=3.0,
                           period=150.0, engine="reference")
    eng = schedule_hvlb_cc(g, tg, variant=variant, alpha_max=3.0,
                           period=150.0, engine="compiled")
    # every grid point exact
    assert np.array_equal(ref.alphas, eng.alphas)
    assert np.array_equal(ref.makespans, eng.makespans)
    assert ref.best_alpha == eng.best_alpha
    assert_identical(ref.best, eng.best)
    # session == shim == reference, on both engines
    policy = (HVLB_CC_A if variant == "A" else HVLB_CC_B)(
        alpha_max=3.0, period=150.0)
    for engine in ("compiled", "reference"):
        plan = Scheduler(tg, engine=engine).submit(g, policy)
        assert np.array_equal(plan.sweep.alphas, ref.alphas)
        assert np.array_equal(plan.sweep.makespans, ref.makespans)
        assert plan.best_alpha == ref.best_alpha
        assert_identical(plan.schedule, ref.best)


def test_rank_matrix_vectorized_bit_identical_paper():
    g, tg = paper_spg(), paper_topology()
    assert np.array_equal(rank_matrix(g, tg), rank_matrix_reference(g, tg))


# ------------------------------------------------------------- random
@pytest.mark.parametrize("seed", range(200))
def test_engine_equivalence_random(seed):
    """Bit-identical schedules on 200 random TGFF graphs; every engine
    output also passes Schedule.validate().  The session API is held to
    the same standard: its best schedule must equal the reference's
    best-of-grid bit for bit."""
    g, tg = _case(seed)
    r = rank_matrix(g, tg)
    assert np.array_equal(r, rank_matrix_reference(g, tg))
    # HPRV_B (indicator) orders any DAG, constrained or not
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    ldet = ldet_cc(g, tg, r)
    refs = {}
    for alpha in (0.0, 0.85):
        ref = list_schedule(g, tg, q, r, alpha=alpha, ldet=ldet)
        eng = inst.schedule(q, alpha=alpha)
        assert_identical(ref, eng)
        eng.validate()
        refs[alpha] = ref
    # session sweep over the same {0.0, 0.85} grid: curve points and the
    # kept best must match the reference runs exactly (shim == session ==
    # reference; the shim path is itself a Scheduler session now)
    plan = Scheduler(tg).submit(g, HVLB_CC_B(alpha_max=0.85,
                                             alpha_step=0.85))
    assert plan.sweep.makespans.tolist() == \
        [refs[0.0].makespan, refs[0.85].makespan]
    ref_best = refs[0.0] if not (refs[0.85].makespan <
                                 refs[0.0].makespan - 1e-12) else refs[0.85]
    assert_identical(plan.schedule, ref_best)


@pytest.mark.parametrize("seed", range(0, 200, 7))
def test_sweep_equivalence_random(seed):
    """The trace-interval-skipping sweep matches the step-by-step reference
    sweep: same curve floats, same best alpha, same best schedule."""
    g, tg = _case(seed)
    ref = schedule_hvlb_cc(g, tg, variant="B", alpha_max=2.0,
                           alpha_step=0.25, engine="reference")
    eng = schedule_hvlb_cc(g, tg, variant="B", alpha_max=2.0,
                           alpha_step=0.25, engine="compiled")
    assert np.array_equal(ref.alphas, eng.alphas)
    assert np.array_equal(ref.makespans, eng.makespans)
    assert ref.best_alpha == eng.best_alpha
    assert_identical(ref.best, eng.best)
    eng.best.validate()


@pytest.mark.parametrize("seed", [2, 11, 23])
def test_engine_equivalence_wide_topology(seed):
    """Equivalence holds beyond the paper's 3-processor star (P=8)."""
    rng = np.random.default_rng(seed)
    tg = fully_switched_topology(
        8, rates=rng.uniform(0.6, 1.2, size=8),
        link_speeds=rng.uniform(0.5, 3.0, size=8))
    g = random_spg(24, rng, ccr=1.0, tg=tg)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    inst = CompiledInstance(g, tg, rank=r)
    for alpha in (0.0, 1.2):
        ref = list_schedule(g, tg, q, r, alpha=alpha)
        eng = inst.schedule(q, alpha=alpha)
        assert_identical(ref, eng)
        eng.validate()


def test_hsv_engine_equivalence_constrained():
    """HSV_CC (HPRV_A queue) equivalence on the constrained family."""
    for seed in range(0, 40):
        rng = np.random.default_rng(10_000 + seed)
        tg = paper_topology(rates=RATE_PATTERNS[seed % 3])
        g = random_spg(int(rng.integers(8, 26)), rng, ccr=1.0, tg=tg,
                       outdeg_constraint=True)
        ref = schedule_hsv_cc(g, tg, engine="reference")
        eng = schedule_hsv_cc(g, tg, engine="compiled")
        assert_identical(ref, eng)
        eng.validate()


def test_adaptive_sweep_never_worse_than_coarse_and_valid():
    """Opt-in coarse-to-fine sweep: valid schedule, best from the curve,
    and at least as good as its own coarse grid by construction."""
    rng = np.random.default_rng(7)
    tg = paper_topology()
    g = random_spg(20, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    res = schedule_hvlb_cc(g, tg, variant="B", alpha_max=2.0,
                           alpha_step=0.05, sweep="adaptive")
    res.best.validate()
    assert res.best.makespan == pytest.approx(res.makespans.min())
    assert any(a == pytest.approx(res.best_alpha)
               for a in res.alphas.tolist())
