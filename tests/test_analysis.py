"""Fixture tests for the static invariant analyzer (repro.analysis).

Each rule gets a violating snippet that MUST produce a finding and a
clean snippet that must NOT (both run through the real CLI entry point
in explicit-path mode, where every rule applies), plus the baseline /
pragma mechanics and the self-check that the shipped repo analyzes
clean.  Everything here is pure-AST — no jax, no kernel execution.
"""
import io
import textwrap
from contextlib import redirect_stderr, redirect_stdout

import pytest

from repro.analysis import main

# ----------------------------------------------------------------------
# tiny harness: run the CLI on fixture sources, capture findings
# ----------------------------------------------------------------------


def run_cli(argv):
    out, err = io.StringIO(), io.StringIO()
    with redirect_stdout(out), redirect_stderr(err):
        code = main(argv)
    return code, out.getvalue(), err.getvalue()


def analyze(tmp_path, source, rules=None, name="fixture.py"):
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    argv = [str(path)]
    if rules:
        argv += ["--rules", rules]
    return run_cli(argv)


def assert_finds(tmp_path, source, rule):
    code, out, _ = analyze(tmp_path, source, rules=rule)
    assert code == 1, f"expected a {rule} finding, got exit {code}:\n{out}"
    assert f"[{rule}]" in out
    return out


def assert_clean(tmp_path, source, rule):
    code, out, _ = analyze(tmp_path, source, rules=rule)
    assert code == 0, f"expected clean under {rule}, got:\n{out}"


# ----------------------------------------------------------------------
# lint rules
# ----------------------------------------------------------------------


class TestFloatArith:
    def test_violation_literal(self, tmp_path):
        out = assert_finds(tmp_path, """
            def pick(best, s):
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")
        assert ":3:" in out          # file:line location

    def test_violation_module_const(self, tmp_path):
        assert_finds(tmp_path, """
            MARGIN = 1e-6
            def skip(a, b):
                return a < b - MARGIN
            """, "float-arith")

    def test_clean_integer_and_comparison(self, tmp_path):
        assert_clean(tmp_path, """
            def pick(best, s, k):
                n = k + 1
                if s.makespan < best.makespan:
                    return s, n
                return best, n
            """, "float-arith")


class TestSentinelScope:
    def test_violation_reference(self, tmp_path):
        assert_finds(tmp_path, """
            from .faults import DOWN_COMP
            def mask(comp):
                comp[0] = DOWN_COMP
            """, "sentinel-scope")

    def test_violation_attribute(self, tmp_path):
        assert_finds(tmp_path, """
            from . import faults
            def check(eft):
                return eft < faults.INFEASIBLE_EFT
            """, "sentinel-scope")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            def mask(comp, value):
                comp[0] = value
            """, "sentinel-scope")


class TestNondeterminism:
    def test_violation_wall_clock(self, tmp_path):
        assert_finds(tmp_path, """
            import time
            def stamp():
                return time.time()
            """, "nondeterminism")

    def test_violation_legacy_np_random(self, tmp_path):
        assert_finds(tmp_path, """
            import numpy as np
            def jitter(n):
                return np.random.rand(n)
            """, "nondeterminism")

    def test_clean_seeded_generator(self, tmp_path):
        assert_clean(tmp_path, """
            import time
            import numpy as np
            def jitter(n, seed):
                t0 = time.monotonic()
                rng = np.random.default_rng(seed)
                return rng.random(n), time.monotonic() - t0
            """, "nondeterminism")

    def test_violation_event_loop_clock(self, tmp_path):
        assert_finds(tmp_path, """
            import asyncio
            def now():
                loop = asyncio.get_running_loop()
                return loop.time()
            """, "nondeterminism")

    def test_event_loop_clock_allowed_behind_pragma(self, tmp_path):
        assert_clean(tmp_path, """
            import asyncio
            def now():
                # analysis: allow[nondeterminism] latency accounting only
                return asyncio.get_running_loop().time()
            """, "nondeterminism")


class TestSetIteration:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            def procs(schedule):
                return [p for p in set(schedule.values())]
            """, "set-iteration")

    def test_clean_sorted(self, tmp_path):
        assert_clean(tmp_path, """
            def procs(schedule):
                return [p for p in sorted(set(schedule.values()))]
            """, "set-iteration")


class TestDeprecationRoute:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            import warnings
            def old_entry():
                warnings.warn("use Scheduler", DeprecationWarning,
                              stacklevel=2)
            """, "deprecation-route")

    def test_clean_warn_once(self, tmp_path):
        assert_clean(tmp_path, """
            from .deprecation import warn_once
            def old_entry():
                warn_once("old_entry", "use Scheduler")
            """, "deprecation-route")


class TestHostSync:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            def fetch(out):
                import jax
                return jax.device_get(out)
            """, "host-sync")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            def fetch(out):
                return out
            """, "host-sync")


class TestUnusedImport:
    def test_violation(self, tmp_path):
        out = assert_finds(tmp_path, """
            import os
            import sys
            def main():
                return sys.argv
            """, "unused-import")
        assert "'os'" in out and "'sys'" not in out

    def test_clean_quoted_annotation_and_all(self, tmp_path):
        assert_clean(tmp_path, """
            from typing import TYPE_CHECKING
            from os import path
            if TYPE_CHECKING:
                from collections import OrderedDict
            __all__ = ["path", "use"]
            def use(d: "OrderedDict") -> "OrderedDict":
                return d
            """, "unused-import")


# ----------------------------------------------------------------------
# kernel rules
# ----------------------------------------------------------------------

# A miniature of the real backend idiom: helper lambdas build the
# BlockSpecs, carried out-blocks have a constant index map, the kernel
# resolves through functools.partial.
KERNEL_TEMPLATE = """\
import functools
import jax.experimental.pallas as pl

def _kernel(x_ref, y_ref, state_ref, *, K):
{body}

def build(B, K, shapes):
    full = lambda *s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    dec = lambda *s: pl.BlockSpec((1,) + s, lambda i: (i,) + (0,) * len(s))
    in_specs = [dec(K)]
    out_specs = [dec(K), full(K)]
    kern = functools.partial(_kernel, K=K)
    return pl.pallas_call(kern, grid={grid}, in_specs=in_specs,
                          out_specs=out_specs, out_shape=shapes)
"""


def kernel_fixture(body, grid="(B,)"):
    indented = "\n".join("    " + ln if ln.strip() else ln
                         for ln in textwrap.dedent(body).strip().splitlines())
    return KERNEL_TEMPLATE.format(body=indented, grid=grid)


GOOD_BODY = """
    val = x_ref[0] + state_ref[0]
    y_ref[0] = val
    state_ref[0] = val
"""


class TestKernelCarried:
    def test_clean_single_commit(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY),
                     "kernel-carried-race,kernel-carried-uncommitted")

    def test_race_double_store(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            val = x_ref[0] + state_ref[0]
            y_ref[0] = val
            state_ref[0] = val
            state_ref[1] = val
            """), "kernel-carried-race")

    def test_race_store_in_loop(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            val = x_ref[0]
            y_ref[0] = val
            for h in range(4):
                state_ref[h] = val
            """), "kernel-carried-race")

    def test_exclusive_branches_are_one_commit(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture("""
            val = x_ref[0]
            y_ref[0] = val
            if K > 1:
                state_ref[0] = val
            else:
                state_ref[0] = -val
            """), "kernel-carried-race,kernel-carried-uncommitted")

    def test_uncommitted(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            y_ref[0] = x_ref[0] + state_ref[0]
            """), "kernel-carried-uncommitted")


class TestKernelGridCarry:
    def test_violation_2d_grid(self, tmp_path):
        # the 1-param `full` index map cannot even name the outer axis
        assert_finds(tmp_path, kernel_fixture(GOOD_BODY, grid="(B, K)"),
                     "kernel-grid-carry")

    def test_violation_2d_grid_leading_axis_ignored(self, tmp_path):
        # 2 params, but the leading (outer) axis is never used: every
        # outer index would revisit — and race on — the same block
        src = kernel_fixture(GOOD_BODY, grid="(B, K)").replace(
            "full = lambda *s: pl.BlockSpec(s, lambda i: (0,) * len(s))",
            "full = lambda *s: pl.BlockSpec(s, lambda a, i: (0,) * len(s))")
        assert_finds(tmp_path, src, "kernel-grid-carry")

    def test_clean_2d_grid_sweep_contract(self, tmp_path):
        # the (A, B) sweep shape: carry confined to the innermost axis,
        # the leading axis addresses an independent state copy per index
        src = kernel_fixture(GOOD_BODY, grid="(B, K)").replace(
            "full = lambda *s: pl.BlockSpec(s, lambda i: (0,) * len(s))",
            "full = lambda *s: pl.BlockSpec((1,) + s,"
            " lambda a, i: (a,) + (0,) * len(s))").replace(
            "dec = lambda *s: pl.BlockSpec((1,) + s, "
            "lambda i: (i,) + (0,) * len(s))",
            "dec = lambda *s: pl.BlockSpec((1, 1) + s, "
            "lambda a, i: (a, i) + (0,) * len(s))")
        assert_clean(tmp_path, src, "kernel-grid-carry")

    def test_clean_1d_grid(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY),
                     "kernel-grid-carry")


# A miniature of the whole-schedule scan idiom: the body function
# threads (lf, pf) through the carry and stacks per-step outputs.
SCAN_TEMPLATE = """\
import jax
import jax.numpy as jnp
from jax import lax

def step(carry, xs):
{body}

def schedule(lf0, pf0, waves):
    (lf, pf), ys = lax.scan(step, (lf0, pf0), waves)
    return lf, pf, ys
"""


def scan_fixture(body):
    indented = "\n".join("    " + ln if ln.strip() else ln
                         for ln in textwrap.dedent(body).strip().splitlines())
    return SCAN_TEMPLATE.format(body=indented)


SCAN_GOOD_BODY = """
    lf, pf = carry
    est = jnp.maximum(lf, xs)
    lf = lf + est
    pf = jnp.minimum(pf, est)
    return (lf, pf), est
"""


class TestScanCarry:
    def test_clean_one_bind_per_leaf(self, tmp_path):
        assert_clean(tmp_path, scan_fixture(SCAN_GOOD_BODY),
                     "scan-carry-race,scan-carry-uncommitted")

    def test_clean_exclusive_branches(self, tmp_path):
        assert_clean(tmp_path, scan_fixture("""
            lf, pf = carry
            est = jnp.maximum(lf, xs)
            if est.ndim:
                lf = lf + est
            else:
                lf = lf - est
            pf = jnp.minimum(pf, est)
            return (lf, pf), est
            """), "scan-carry-race,scan-carry-uncommitted")

    def test_clean_nested_function_scope_excluded(self, tmp_path):
        # a fori_loop body threads its own state tuple; its bindings
        # are not writes to the outer carry leaf
        assert_clean(tmp_path, scan_fixture("""
            lf, pf = carry
            def slot(b, st):
                lf, pf = st
                lf = lf + b
                return (lf, pf)
            lf, pf = lax.fori_loop(0, 4, slot, (lf, pf))
            return (lf, pf), lf
            """), "scan-carry-race,scan-carry-uncommitted")

    def test_race_double_bind(self, tmp_path):
        out = assert_finds(tmp_path, scan_fixture("""
            lf, pf = carry
            lf = lf + xs
            lf = lf * 2.0
            pf = jnp.minimum(pf, lf)
            return (lf, pf), lf
            """), "scan-carry-race")
        assert "2 bindings" in out

    def test_race_bind_in_loop(self, tmp_path):
        assert_finds(tmp_path, scan_fixture("""
            lf, pf = carry
            for h in range(4):
                lf = lf + xs
            pf = jnp.minimum(pf, lf)
            return (lf, pf), lf
            """), "scan-carry-race")

    def test_race_duplicate_carry_leaf(self, tmp_path):
        out = assert_finds(tmp_path, scan_fixture("""
            lf, pf = carry
            lf = lf + xs
            return (lf, lf), pf
            """), "scan-carry-race")
        assert "alias" in out

    def test_uncommitted_leaf(self, tmp_path):
        out = assert_finds(tmp_path, scan_fixture("""
            lf, pf = carry
            lf = lf + xs
            return (lf, pf), lf
            """), "scan-carry-uncommitted")
        assert "pf" in out

    def test_initial_unpack_not_counted_as_bind(self, tmp_path):
        # `lf, pf = carry` alone must read as ZERO commits, not one
        assert_finds(tmp_path, scan_fixture("""
            lf, pf = carry
            return (lf, pf), xs
            """), "scan-carry-uncommitted")


class TestKernelArity:
    def test_violation(self, tmp_path):
        # 3 kernel refs but 1+3 specs supplied
        src = kernel_fixture(GOOD_BODY).replace(
            "out_specs = [dec(K), full(K)]",
            "out_specs = [dec(K), dec(K), full(K)]")
        assert_finds(tmp_path, src, "kernel-arity")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY), "kernel-arity")


class TestKernelTilePad:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            from .layout import pad_dim
            def dims(P, L):
                return pad_dim(P, 4), pad_dim(L, 128)
            """, "kernel-tile-pad")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            from .layout import LANE, SUBLANE_F32, pad_dim
            def dims(P, L, tile):
                if tile:
                    return pad_dim(P, SUBLANE_F32), pad_dim(L, LANE)
                return pad_dim(P, 1), pad_dim(L, 1)
            """, "kernel-tile-pad")


class TestKernelDtype:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            import jax.numpy as jnp
            val = x_ref[0].astype(jnp.float64)
            y_ref[0] = val
            state_ref[0] = val
            """), "kernel-dtype")

    def test_clean_ref_dtype(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture("""
            f = x_ref.dtype
            val = x_ref[0].astype(f)
            y_ref[0] = val
            state_ref[0] = val
            """), "kernel-dtype")


class TestKernelRtolSite:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            F32_NEAR_TIE_RTOL = 1e-5
            def near(a, b):
                return abs(a - b) <= F32_NEAR_TIE_RTOL * abs(b)
            """, "kernel-rtol-site")

    def test_clean_definition_only(self, tmp_path):
        assert_clean(tmp_path, """
            F32_NEAR_TIE_RTOL = 1e-5
            """, "kernel-rtol-site")


# ----------------------------------------------------------------------
# typing gate rules
# ----------------------------------------------------------------------

PROTOCOL = """
    import abc

    class CandidateEvaluator(abc.ABC):
        name = "base"

        @abc.abstractmethod
        def _alloc(self):
            ...

        @abc.abstractmethod
        def evaluate(self, j):
            ...

        def evaluate_batch(self, js):
            return [self.evaluate(j) for j in js]
"""


class TestTypingGate:
    def test_protocol_missing(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class HalfBackend(CandidateEvaluator):
                name = "half"
                def _alloc(self):
                    ...
            """, "protocol-missing")

    def test_protocol_signature(self, tmp_path):
        out = assert_finds(tmp_path, PROTOCOL + """
            class RenamedBackend(CandidateEvaluator):
                name = "renamed"
                def _alloc(self):
                    ...
                def evaluate(self, task):
                    ...
            """, "protocol-signature")
        assert "evaluate" in out

    def test_protocol_extra_arg_without_default(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class GreedyBackend(CandidateEvaluator):
                name = "greedy"
                def _alloc(self):
                    ...
                def evaluate(self, j, extra):
                    ...
            """, "protocol-signature")

    def test_backend_name(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class AnonBackend(CandidateEvaluator):
                def _alloc(self):
                    ...
                def evaluate(self, j):
                    ...
            """, "backend-name")

    def test_clean_backend(self, tmp_path):
        assert_clean(tmp_path, PROTOCOL + """
            class GoodBackend(CandidateEvaluator):
                name = "good"
                def _alloc(self):
                    ...
                def evaluate(self, j):
                    ...
                def evaluate_batch(self, js, chunk=4):
                    return super().evaluate_batch(js)
            """, "protocol-missing,protocol-signature,backend-name")


# ----------------------------------------------------------------------
# concurrency rules (service-layer race detector)
# ----------------------------------------------------------------------

# the hybrid idiom under test: an async front door, a per-lane thread
# executor, a threading.Lock around shared state
SVC_HEADER = """
            import asyncio
            import threading
            import time
            from concurrent.futures import ThreadPoolExecutor
"""


class TestRaceUnguardedShared:
    def test_violation_loop_writes_worker_reads(self, tmp_path):
        out = assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._ex = ThreadPoolExecutor(1)
                    self._stats = {}

                async def request(self, key):
                    self._stats[key] = 1
                    loop = asyncio.get_running_loop()
                    await loop.run_in_executor(self._ex, self._work, key)

                def _work(self, key):
                    with self._lock:
                        self._stats[key] += 1

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "race-unguarded-shared")
        assert "self._stats" in out and "self._lock" in out

    def test_violation_no_lock_anywhere(self, tmp_path):
        out = assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._ex = ThreadPoolExecutor(1)
                    self._seen = set()

                async def request(self, key):
                    if key in self._seen:
                        return
                    loop = asyncio.get_running_loop()
                    await loop.run_in_executor(self._ex, self._work, key)

                def _work(self, key):
                    self._seen.add(key)

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "race-unguarded-shared")
        assert "no access holds a lock" in out

    def test_clean_every_site_guarded(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._ex = ThreadPoolExecutor(1)
                    self._stats = {}
                    self.batch = 8        # immutable config: not flagged

                async def request(self, key):
                    with self._lock:
                        self._stats[key] = self.batch
                    loop = asyncio.get_running_loop()
                    await loop.run_in_executor(self._ex, self._work, key)

                def _work(self, key):
                    with self._lock:
                        self._stats[key] += self.batch

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "race-unguarded-shared")


class TestAwaitUnderLock:
    def test_violation_await(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()

                async def tick(self):
                    with self._lock:
                        await asyncio.sleep(0.1)
            """, "race-await-under-lock")

    def test_violation_lane_lock_acquisition(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self, workers):
                    self._lock = threading.Lock()
                    self._locks = [asyncio.Lock() for _ in range(workers)]

                async def flush(self, lane):
                    with self._lock:
                        async with self._locks[lane]:
                            pass
            """, "race-await-under-lock")

    def test_clean_await_outside_and_alias_resolution(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.n = 0

                async def tick(self):
                    lock = self._lock
                    with lock:
                        self.n += 1
                    await asyncio.sleep(0.1)
            """, "race-await-under-lock")


class TestLoopBlockingCall:
    def test_violation_time_sleep(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            async def backoff():
                time.sleep(0.5)
            """, "loop-blocking-call")

    def test_violation_direct_scheduler_call(self, tmp_path):
        out = assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self, sched):
                    self.sched = sched

                async def replan(self, graph):
                    self.sched.submit(graph)
            """, "loop-blocking-call")
        assert "Scheduler.submit" in out

    def test_violation_future_result(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            async def wait_for(fut):
                return fut.result()
            """, "loop-blocking-call")

    def test_clean_worker_side_and_executor_routing(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self, sched):
                    self.sched = sched
                    self._ex = ThreadPoolExecutor(1)

                async def replan(self, graph):
                    await asyncio.sleep(0.01)
                    loop = asyncio.get_running_loop()
                    return await loop.run_in_executor(
                        self._ex, self._run, graph)

                def _run(self, graph):
                    time.sleep(0.001)     # blocking is fine on a worker
                    return self.sched.submit(graph)

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "loop-blocking-call")


class TestCrossThreadFuture:
    def test_violation_set_result_from_worker(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            def _resolve(fut, value):
                fut.set_result(value)

            class Svc:
                def __init__(self):
                    self._ex = ThreadPoolExecutor(1)

                async def run(self, fut):
                    loop = asyncio.get_running_loop()
                    await loop.run_in_executor(self._ex, _resolve, fut, 1)

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "race-cross-thread-future")

    def test_clean_call_soon_threadsafe_discipline(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            def _set_result(fut, value):
                if not fut.done():
                    fut.set_result(value)

            def _resolve(fut, value):
                fut.get_loop().call_soon_threadsafe(_set_result, fut, value)

            class Svc:
                def __init__(self):
                    self._ex = ThreadPoolExecutor(1)

                async def run(self, fut):
                    loop = asyncio.get_running_loop()
                    await loop.run_in_executor(self._ex, _resolve, fut, 1)

                def close(self):
                    self._ex.shutdown(wait=True)
            """, "race-cross-thread-future")


class TestLeakExecutor:
    def test_violation_attribute_never_joined(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._ex = ThreadPoolExecutor(4)

                async def run(self, fn):
                    loop = asyncio.get_running_loop()
                    return await loop.run_in_executor(self._ex, fn)
            """, "leak-executor")

    def test_violation_local_never_shut_down(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            def fan_out(jobs):
                ex = ThreadPoolExecutor(2)
                for j in jobs:
                    ex.submit(j)
            """, "leak-executor")

    def test_clean_joined_in_close_and_scoped_local(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._ex = ThreadPoolExecutor(4)

                async def run(self, fn):
                    loop = asyncio.get_running_loop()
                    return await loop.run_in_executor(self._ex, fn)

                def close(self):
                    self._ex.shutdown(wait=True)

            def fan_out(jobs):
                with ThreadPoolExecutor(2) as ex:
                    return [ex.submit(j) for j in jobs]
            """, "leak-executor")


class TestGcTaskRef:
    def test_violation_fire_and_forget(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            async def arm(coro):
                asyncio.create_task(coro)
            """, "gc-task-ref")

    def test_violation_assigned_but_unanchored(self, tmp_path):
        assert_finds(tmp_path, SVC_HEADER + """
            async def arm(coro):
                task = asyncio.ensure_future(coro)
                print("armed", task is not None)
            """, "gc-task-ref")

    def test_clean_anchored_in_container(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            class Svc:
                def __init__(self):
                    self._tasks = set()

                async def arm(self, coro):
                    task = asyncio.get_running_loop().create_task(coro)
                    self._tasks.add(task)
                    task.add_done_callback(self._tasks.discard)
            """, "gc-task-ref")

    def test_clean_awaited(self, tmp_path):
        assert_clean(tmp_path, SVC_HEADER + """
            async def arm(coro):
                task = asyncio.ensure_future(coro)
                return await task
            """, "gc-task-ref")


# ----------------------------------------------------------------------
# suppression pragma + ratchet baseline mechanics
# ----------------------------------------------------------------------


class TestPragma:
    def test_justified_pragma_suppresses(self, tmp_path):
        assert_clean(tmp_path, """
            def pick(best, s):
                # analysis: allow[float-arith] comparison epsilon, not a decision value
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")

    def test_pragma_without_reason_is_a_finding(self, tmp_path):
        code, out, _ = analyze(tmp_path, """
            def pick(best, s):
                # analysis: allow[float-arith]
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """)
        assert code == 1
        assert "[allow-without-reason]" in out

    def test_pragma_is_rule_specific(self, tmp_path):
        assert_finds(tmp_path, """
            def pick(best, s):
                # analysis: allow[host-sync] wrong rule id
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")


class TestBaseline:
    SRC = """
        def pick(best, s):
            if s.makespan < best.makespan - 1e-12:
                return s
            return best
        """

    def test_baselined_finding_passes_and_stale_fails(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text(textwrap.dedent(self.SRC))
        baseline = tmp_path / "baseline.txt"

        code, _, _ = run_cli([str(path), "--rules", "float-arith",
                              "--baseline", str(baseline),
                              "--write-baseline"])
        assert code == 0
        assert "float-arith" in baseline.read_text()

        code, out, _ = run_cli([str(path), "--rules", "float-arith",
                                "--baseline", str(baseline)])
        assert code == 0, out          # tolerated by the ratchet

        # fix the code: the baseline entry goes stale and must be removed
        path.write_text(textwrap.dedent("""
            def pick(best, s):
                if s.makespan < best.makespan:
                    return s
                return best
            """))
        code, out, _ = run_cli([str(path), "--rules", "float-arith",
                                "--baseline", str(baseline)])
        assert code == 1
        assert "stale baseline entry" in out

    def test_missing_baseline_file_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, _, err = run_cli([str(path),
                                "--baseline", str(tmp_path / "nope.txt")])
        assert code == 2
        assert "does not exist" in err


# ----------------------------------------------------------------------
# CLI plumbing + repo self-check
# ----------------------------------------------------------------------


class TestCli:
    def test_unknown_rule_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, _, err = run_cli([str(path), "--rules", "no-such-rule"])
        assert code == 2
        assert "no-such-rule" in err

    def test_syntax_error_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("def broken(:\n")
        code, _, err = run_cli([str(path)])
        assert code == 2
        assert "syntax error" in err

    def test_list_rules_covers_all_passes(self):
        code, out, _ = run_cli(["--list-rules"])
        assert code == 0
        rules = set(out.split())
        for rule in ("kernel-carried-race", "kernel-tile-pad",
                     "kernel-dtype", "float-arith", "sentinel-scope",
                     "nondeterminism", "host-sync", "unused-import",
                     "protocol-missing", "protocol-signature",
                     "race-unguarded-shared", "race-await-under-lock",
                     "loop-blocking-call", "race-cross-thread-future",
                     "leak-executor", "gc-task-ref"):
            assert rule in rules

    def test_findings_carry_file_line_locations(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("import os\nx = 1\n")
        code, out, _ = run_cli([str(path), "--rules", "unused-import"])
        assert code == 1
        assert f"{path}:1: [unused-import]" in out

    def test_directory_arguments_expand_sorted_and_deduped(self, tmp_path):
        (tmp_path / "b.py").write_text("import os\nx = 1\n")
        (tmp_path / "a.py").write_text("y = 2\n")
        # the directory overlaps the explicit file: analyzed once
        code, out, _ = run_cli([str(tmp_path), str(tmp_path / "b.py"),
                                "--rules", "unused-import"])
        assert code == 1
        assert out.count("[unused-import]") == 1
        assert "across 2 file(s)" in out

    def test_missing_path_is_config_error(self, tmp_path):
        code, _, err = run_cli([str(tmp_path / "nope.py")])
        assert code == 2
        assert "no such file or directory" in err

    def test_repo_mode_paths_filter(self):
        code, out, _ = run_cli(["--paths", "src/repro/service/"])
        assert code == 0, out
        assert "clean" in out

    def test_paths_filter_without_match_is_config_error(self):
        code, _, err = run_cli(["--paths", "src/repro/nope/"])
        assert code == 2
        assert "matches no repo files" in err

    def test_paths_filter_rejected_in_explicit_mode(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, _, err = run_cli([str(path), "--paths", "src/repro/"])
        assert code == 2
        assert "repo-mode" in err


class TestJsonFormat:
    def test_one_object_per_line_with_schema(self, tmp_path):
        import json
        path = tmp_path / "fixture.py"
        path.write_text("import os\nx = 1\n")
        code, out, _ = run_cli([str(path), "--rules", "unused-import",
                                "--format", "json"])
        assert code == 1
        objs = [json.loads(line) for line in out.splitlines()]
        assert len(objs) == 1
        (f,) = objs
        assert list(f) == ["rule", "path", "line", "source",
                           "fingerprint", "message"]
        assert f["rule"] == "unused-import"
        assert f["path"] == str(path)
        assert f["line"] == 1
        assert f["source"] == "import os"
        assert f["fingerprint"] == f"{path}::unused-import::import os"
        assert "'os'" in f["message"]

    def test_clean_json_run_prints_nothing(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, out, _ = run_cli([str(path), "--format", "json"])
        assert code == 0
        assert out == ""

    def test_stale_baseline_entry_as_object(self, tmp_path):
        import json
        path = tmp_path / "fixture.py"
        path.write_text("import os\nx = 1\n")
        baseline = tmp_path / "baseline.txt"
        code, _, _ = run_cli([str(path), "--rules", "unused-import",
                              "--baseline", str(baseline),
                              "--write-baseline"])
        assert code == 0
        path.write_text("x = 1\n")       # fix it: entry goes stale
        code, out, _ = run_cli([str(path), "--rules", "unused-import",
                                "--baseline", str(baseline),
                                "--format", "json"])
        assert code == 1
        (obj,) = [json.loads(line) for line in out.splitlines()]
        assert obj["rule"] == "stale-baseline-entry"
        assert obj["fingerprint"].endswith("::unused-import::import os")


class TestProjectIndex:
    def test_repeated_load_parses_once(self, tmp_path):
        from repro.analysis.index import ProjectIndex
        path = tmp_path / "mod.py"
        path.write_text("x = 1\n")
        index = ProjectIndex()
        sf1 = index.load(path, "mod.py")
        sf2 = index.load(path, "mod.py")
        assert sf1 is sf2
        assert index.parse_count == 1

    def test_all_passes_share_one_parse_per_file(self, tmp_path,
                                                 monkeypatch):
        """The refactor's point: a full CLI run (all four passes) parses
        each file exactly once."""
        import ast as ast_module
        from repro.analysis import index as index_module
        counts = {}
        real_parse = ast_module.parse

        def counting_parse(source, filename="<unknown>", *a, **kw):
            counts[filename] = counts.get(filename, 0) + 1
            return real_parse(source, filename, *a, **kw)

        monkeypatch.setattr(index_module.ast, "parse", counting_parse)
        paths = []
        for name in ("one.py", "two.py", "three.py"):
            p = tmp_path / name
            p.write_text("import os\nx = 1\n")
            paths.append(str(p))
        code, _, _ = run_cli(paths)
        assert code == 1                  # unused-import fires
        assert counts == {p: 1 for p in paths}

    def test_syntax_error_recorded_not_retried(self, tmp_path):
        from repro.analysis.index import ProjectIndex
        path = tmp_path / "bad.py"
        path.write_text("def broken(:\n")
        index = ProjectIndex()
        assert index.load(path, "bad.py") is None
        assert index.load(path, "bad.py") is None
        assert len(index.errors) == 1
        assert index.parse_count == 0


def test_shipped_repo_analyzes_clean():
    """The acceptance gate: repo mode (scoped rules + committed ratchet
    baseline) over the shipped tree exits 0."""
    code, out, _ = run_cli([])
    assert code == 0, f"shipped tree has analyzer findings:\n{out}"
    assert "clean" in out
