"""Fixture tests for the static invariant analyzer (repro.analysis).

Each rule gets a violating snippet that MUST produce a finding and a
clean snippet that must NOT (both run through the real CLI entry point
in explicit-path mode, where every rule applies), plus the baseline /
pragma mechanics and the self-check that the shipped repo analyzes
clean.  Everything here is pure-AST — no jax, no kernel execution.
"""
import io
import textwrap
from contextlib import redirect_stderr, redirect_stdout

import pytest

from repro.analysis import main

# ----------------------------------------------------------------------
# tiny harness: run the CLI on fixture sources, capture findings
# ----------------------------------------------------------------------


def run_cli(argv):
    out, err = io.StringIO(), io.StringIO()
    with redirect_stdout(out), redirect_stderr(err):
        code = main(argv)
    return code, out.getvalue(), err.getvalue()


def analyze(tmp_path, source, rules=None, name="fixture.py"):
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    argv = [str(path)]
    if rules:
        argv += ["--rules", rules]
    return run_cli(argv)


def assert_finds(tmp_path, source, rule):
    code, out, _ = analyze(tmp_path, source, rules=rule)
    assert code == 1, f"expected a {rule} finding, got exit {code}:\n{out}"
    assert f"[{rule}]" in out
    return out


def assert_clean(tmp_path, source, rule):
    code, out, _ = analyze(tmp_path, source, rules=rule)
    assert code == 0, f"expected clean under {rule}, got:\n{out}"


# ----------------------------------------------------------------------
# lint rules
# ----------------------------------------------------------------------


class TestFloatArith:
    def test_violation_literal(self, tmp_path):
        out = assert_finds(tmp_path, """
            def pick(best, s):
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")
        assert ":3:" in out          # file:line location

    def test_violation_module_const(self, tmp_path):
        assert_finds(tmp_path, """
            MARGIN = 1e-6
            def skip(a, b):
                return a < b - MARGIN
            """, "float-arith")

    def test_clean_integer_and_comparison(self, tmp_path):
        assert_clean(tmp_path, """
            def pick(best, s, k):
                n = k + 1
                if s.makespan < best.makespan:
                    return s, n
                return best, n
            """, "float-arith")


class TestSentinelScope:
    def test_violation_reference(self, tmp_path):
        assert_finds(tmp_path, """
            from .faults import DOWN_COMP
            def mask(comp):
                comp[0] = DOWN_COMP
            """, "sentinel-scope")

    def test_violation_attribute(self, tmp_path):
        assert_finds(tmp_path, """
            from . import faults
            def check(eft):
                return eft < faults.INFEASIBLE_EFT
            """, "sentinel-scope")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            def mask(comp, value):
                comp[0] = value
            """, "sentinel-scope")


class TestNondeterminism:
    def test_violation_wall_clock(self, tmp_path):
        assert_finds(tmp_path, """
            import time
            def stamp():
                return time.time()
            """, "nondeterminism")

    def test_violation_legacy_np_random(self, tmp_path):
        assert_finds(tmp_path, """
            import numpy as np
            def jitter(n):
                return np.random.rand(n)
            """, "nondeterminism")

    def test_clean_seeded_generator(self, tmp_path):
        assert_clean(tmp_path, """
            import time
            import numpy as np
            def jitter(n, seed):
                t0 = time.monotonic()
                rng = np.random.default_rng(seed)
                return rng.random(n), time.monotonic() - t0
            """, "nondeterminism")

    def test_violation_event_loop_clock(self, tmp_path):
        assert_finds(tmp_path, """
            import asyncio
            def now():
                loop = asyncio.get_running_loop()
                return loop.time()
            """, "nondeterminism")

    def test_event_loop_clock_allowed_behind_pragma(self, tmp_path):
        assert_clean(tmp_path, """
            import asyncio
            def now():
                # analysis: allow[nondeterminism] latency accounting only
                return asyncio.get_running_loop().time()
            """, "nondeterminism")


class TestSetIteration:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            def procs(schedule):
                return [p for p in set(schedule.values())]
            """, "set-iteration")

    def test_clean_sorted(self, tmp_path):
        assert_clean(tmp_path, """
            def procs(schedule):
                return [p for p in sorted(set(schedule.values()))]
            """, "set-iteration")


class TestDeprecationRoute:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            import warnings
            def old_entry():
                warnings.warn("use Scheduler", DeprecationWarning,
                              stacklevel=2)
            """, "deprecation-route")

    def test_clean_warn_once(self, tmp_path):
        assert_clean(tmp_path, """
            from .deprecation import warn_once
            def old_entry():
                warn_once("old_entry", "use Scheduler")
            """, "deprecation-route")


class TestHostSync:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            def fetch(out):
                import jax
                return jax.device_get(out)
            """, "host-sync")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            def fetch(out):
                return out
            """, "host-sync")


class TestUnusedImport:
    def test_violation(self, tmp_path):
        out = assert_finds(tmp_path, """
            import os
            import sys
            def main():
                return sys.argv
            """, "unused-import")
        assert "'os'" in out and "'sys'" not in out

    def test_clean_quoted_annotation_and_all(self, tmp_path):
        assert_clean(tmp_path, """
            from typing import TYPE_CHECKING
            from os import path
            if TYPE_CHECKING:
                from collections import OrderedDict
            __all__ = ["path", "use"]
            def use(d: "OrderedDict") -> "OrderedDict":
                return d
            """, "unused-import")


# ----------------------------------------------------------------------
# kernel rules
# ----------------------------------------------------------------------

# A miniature of the real backend idiom: helper lambdas build the
# BlockSpecs, carried out-blocks have a constant index map, the kernel
# resolves through functools.partial.
KERNEL_TEMPLATE = """\
import functools
import jax.experimental.pallas as pl

def _kernel(x_ref, y_ref, state_ref, *, K):
{body}

def build(B, K, shapes):
    full = lambda *s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    dec = lambda *s: pl.BlockSpec((1,) + s, lambda i: (i,) + (0,) * len(s))
    in_specs = [dec(K)]
    out_specs = [dec(K), full(K)]
    kern = functools.partial(_kernel, K=K)
    return pl.pallas_call(kern, grid={grid}, in_specs=in_specs,
                          out_specs=out_specs, out_shape=shapes)
"""


def kernel_fixture(body, grid="(B,)"):
    indented = "\n".join("    " + ln if ln.strip() else ln
                         for ln in textwrap.dedent(body).strip().splitlines())
    return KERNEL_TEMPLATE.format(body=indented, grid=grid)


GOOD_BODY = """
    val = x_ref[0] + state_ref[0]
    y_ref[0] = val
    state_ref[0] = val
"""


class TestKernelCarried:
    def test_clean_single_commit(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY),
                     "kernel-carried-race,kernel-carried-uncommitted")

    def test_race_double_store(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            val = x_ref[0] + state_ref[0]
            y_ref[0] = val
            state_ref[0] = val
            state_ref[1] = val
            """), "kernel-carried-race")

    def test_race_store_in_loop(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            val = x_ref[0]
            y_ref[0] = val
            for h in range(4):
                state_ref[h] = val
            """), "kernel-carried-race")

    def test_exclusive_branches_are_one_commit(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture("""
            val = x_ref[0]
            y_ref[0] = val
            if K > 1:
                state_ref[0] = val
            else:
                state_ref[0] = -val
            """), "kernel-carried-race,kernel-carried-uncommitted")

    def test_uncommitted(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            y_ref[0] = x_ref[0] + state_ref[0]
            """), "kernel-carried-uncommitted")


class TestKernelGridCarry:
    def test_violation_2d_grid(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture(GOOD_BODY, grid="(B, K)"),
                     "kernel-grid-carry")

    def test_clean_1d_grid(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY),
                     "kernel-grid-carry")


class TestKernelArity:
    def test_violation(self, tmp_path):
        # 3 kernel refs but 1+3 specs supplied
        src = kernel_fixture(GOOD_BODY).replace(
            "out_specs = [dec(K), full(K)]",
            "out_specs = [dec(K), dec(K), full(K)]")
        assert_finds(tmp_path, src, "kernel-arity")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture(GOOD_BODY), "kernel-arity")


class TestKernelTilePad:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            from .layout import pad_dim
            def dims(P, L):
                return pad_dim(P, 4), pad_dim(L, 128)
            """, "kernel-tile-pad")

    def test_clean(self, tmp_path):
        assert_clean(tmp_path, """
            from .layout import LANE, SUBLANE_F32, pad_dim
            def dims(P, L, tile):
                if tile:
                    return pad_dim(P, SUBLANE_F32), pad_dim(L, LANE)
                return pad_dim(P, 1), pad_dim(L, 1)
            """, "kernel-tile-pad")


class TestKernelDtype:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, kernel_fixture("""
            import jax.numpy as jnp
            val = x_ref[0].astype(jnp.float64)
            y_ref[0] = val
            state_ref[0] = val
            """), "kernel-dtype")

    def test_clean_ref_dtype(self, tmp_path):
        assert_clean(tmp_path, kernel_fixture("""
            f = x_ref.dtype
            val = x_ref[0].astype(f)
            y_ref[0] = val
            state_ref[0] = val
            """), "kernel-dtype")


class TestKernelRtolSite:
    def test_violation(self, tmp_path):
        assert_finds(tmp_path, """
            F32_NEAR_TIE_RTOL = 1e-5
            def near(a, b):
                return abs(a - b) <= F32_NEAR_TIE_RTOL * abs(b)
            """, "kernel-rtol-site")

    def test_clean_definition_only(self, tmp_path):
        assert_clean(tmp_path, """
            F32_NEAR_TIE_RTOL = 1e-5
            """, "kernel-rtol-site")


# ----------------------------------------------------------------------
# typing gate rules
# ----------------------------------------------------------------------

PROTOCOL = """
    import abc

    class CandidateEvaluator(abc.ABC):
        name = "base"

        @abc.abstractmethod
        def _alloc(self):
            ...

        @abc.abstractmethod
        def evaluate(self, j):
            ...

        def evaluate_batch(self, js):
            return [self.evaluate(j) for j in js]
"""


class TestTypingGate:
    def test_protocol_missing(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class HalfBackend(CandidateEvaluator):
                name = "half"
                def _alloc(self):
                    ...
            """, "protocol-missing")

    def test_protocol_signature(self, tmp_path):
        out = assert_finds(tmp_path, PROTOCOL + """
            class RenamedBackend(CandidateEvaluator):
                name = "renamed"
                def _alloc(self):
                    ...
                def evaluate(self, task):
                    ...
            """, "protocol-signature")
        assert "evaluate" in out

    def test_protocol_extra_arg_without_default(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class GreedyBackend(CandidateEvaluator):
                name = "greedy"
                def _alloc(self):
                    ...
                def evaluate(self, j, extra):
                    ...
            """, "protocol-signature")

    def test_backend_name(self, tmp_path):
        assert_finds(tmp_path, PROTOCOL + """
            class AnonBackend(CandidateEvaluator):
                def _alloc(self):
                    ...
                def evaluate(self, j):
                    ...
            """, "backend-name")

    def test_clean_backend(self, tmp_path):
        assert_clean(tmp_path, PROTOCOL + """
            class GoodBackend(CandidateEvaluator):
                name = "good"
                def _alloc(self):
                    ...
                def evaluate(self, j):
                    ...
                def evaluate_batch(self, js, chunk=4):
                    return super().evaluate_batch(js)
            """, "protocol-missing,protocol-signature,backend-name")


# ----------------------------------------------------------------------
# suppression pragma + ratchet baseline mechanics
# ----------------------------------------------------------------------


class TestPragma:
    def test_justified_pragma_suppresses(self, tmp_path):
        assert_clean(tmp_path, """
            def pick(best, s):
                # analysis: allow[float-arith] comparison epsilon, not a decision value
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")

    def test_pragma_without_reason_is_a_finding(self, tmp_path):
        code, out, _ = analyze(tmp_path, """
            def pick(best, s):
                # analysis: allow[float-arith]
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """)
        assert code == 1
        assert "[allow-without-reason]" in out

    def test_pragma_is_rule_specific(self, tmp_path):
        assert_finds(tmp_path, """
            def pick(best, s):
                # analysis: allow[host-sync] wrong rule id
                if s.makespan < best.makespan - 1e-12:
                    return s
                return best
            """, "float-arith")


class TestBaseline:
    SRC = """
        def pick(best, s):
            if s.makespan < best.makespan - 1e-12:
                return s
            return best
        """

    def test_baselined_finding_passes_and_stale_fails(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text(textwrap.dedent(self.SRC))
        baseline = tmp_path / "baseline.txt"

        code, _, _ = run_cli([str(path), "--rules", "float-arith",
                              "--baseline", str(baseline),
                              "--write-baseline"])
        assert code == 0
        assert "float-arith" in baseline.read_text()

        code, out, _ = run_cli([str(path), "--rules", "float-arith",
                                "--baseline", str(baseline)])
        assert code == 0, out          # tolerated by the ratchet

        # fix the code: the baseline entry goes stale and must be removed
        path.write_text(textwrap.dedent("""
            def pick(best, s):
                if s.makespan < best.makespan:
                    return s
                return best
            """))
        code, out, _ = run_cli([str(path), "--rules", "float-arith",
                                "--baseline", str(baseline)])
        assert code == 1
        assert "stale baseline entry" in out

    def test_missing_baseline_file_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, _, err = run_cli([str(path),
                                "--baseline", str(tmp_path / "nope.txt")])
        assert code == 2
        assert "does not exist" in err


# ----------------------------------------------------------------------
# CLI plumbing + repo self-check
# ----------------------------------------------------------------------


class TestCli:
    def test_unknown_rule_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("x = 1\n")
        code, _, err = run_cli([str(path), "--rules", "no-such-rule"])
        assert code == 2
        assert "no-such-rule" in err

    def test_syntax_error_is_config_error(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("def broken(:\n")
        code, _, err = run_cli([str(path)])
        assert code == 2
        assert "syntax error" in err

    def test_list_rules_covers_all_passes(self):
        code, out, _ = run_cli(["--list-rules"])
        assert code == 0
        rules = set(out.split())
        for rule in ("kernel-carried-race", "kernel-tile-pad",
                     "kernel-dtype", "float-arith", "sentinel-scope",
                     "nondeterminism", "host-sync", "unused-import",
                     "protocol-missing", "protocol-signature"):
            assert rule in rules

    def test_findings_carry_file_line_locations(self, tmp_path):
        path = tmp_path / "fixture.py"
        path.write_text("import os\nx = 1\n")
        code, out, _ = run_cli([str(path), "--rules", "unused-import"])
        assert code == 1
        assert f"{path}:1: [unused-import]" in out


def test_shipped_repo_analyzes_clean():
    """The acceptance gate: repo mode (scoped rules + committed ratchet
    baseline) over the shipped tree exits 0."""
    code, out, _ = run_cli([])
    assert code == 0, f"shipped tree has analyzer findings:\n{out}"
    assert "clean" in out
