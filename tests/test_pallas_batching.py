"""Level-batched decision layer + compilable f32 Pallas path.

Four contracts pinned here:

* **Batch invariance** — the engine's level-batch grouping (waves of
  independent, same-rank-level tasks) never changes a decision: any
  batch cap produces the identical schedule on every backend, batches
  never contain a precedence edge, and trace records carry identical
  batch ids across backends (so pallas <-> scalar resume works even
  when the resume position splits a wave).
* **O(levels) host traffic** — the batched pallas backend pays exactly
  one kernel launch and one blocking device->host transfer per wave,
  and the HVLB_CC (B) queue decomposes into roughly one wave per rank
  level.
* **Mode selection** — ``REPRO_PALLAS_INTERPRET`` / ``REPRO_PALLAS_DTYPE``
  / ``REPRO_PALLAS_TILE`` force the interpreter/compiled dispatch, the
  kernel dtype, and tile padding; the compiled defaults are f32 +
  tile-padded (lane/sublane multiples), the interpreter defaults f64 +
  unpadded.
* **f32 near-tie policy** — in float32 the schedule is
  decision-identical to the f64 scalar reference except where two
  candidates' selection values differ by less than
  ``F32_NEAR_TIE_RTOL`` (relative); inside that band the winner is the
  deterministic f32-lexicographic ``(value, EFT, proc)`` argmin
  (first index on exact f32 ties) — fuzzed across the boundary below.
"""
from collections import Counter

import numpy as np
import pytest

from repro.core import (HVLB_CC_B, CompiledInstance, Scheduler, paper_spg,
                        paper_topology, random_spg)
from repro.core.engine import DEFAULT_BATCH_MAX
from repro.core.graph import SPG
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.topology import fully_switched_topology


def _queue_for(g, tg):
    r = rank_matrix(g, tg)
    return r, priority_queue(hprv_b(g, tg, r), r.mean(1))


def assert_identical(a, b):
    assert np.array_equal(a.proc, b.proc)
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)
    assert set(a.messages) == set(b.messages)
    for e, ma in a.messages.items():
        mb = b.messages[e]
        assert ma.route == mb.route
        assert ma.intervals == mb.intervals


# ------------------------------------------------------- batch invariance
@pytest.mark.parametrize("cap", [1, 2, 5, None])
def test_batch_cap_invariance(cap):
    """Any batch cap yields the bit-identical schedule (scalar/vector),
    and every batch respects the level/independence/cap invariants."""
    tg = paper_topology()
    for seed in (0, 7):
        g = random_spg(30, np.random.default_rng(seed), ccr=1.0, tg=tg,
                       outdeg_constraint=True)
        r, q = _queue_for(g, tg)
        inst = CompiledInstance(g, tg, rank=r)
        ref = inst.schedule(q, alpha=0.8, backend="scalar", batch=1)
        for backend in ("scalar", "vector"):
            s, _, tr = inst.schedule_traced(q, 0.8, backend=backend,
                                            batch=cap)
            assert_identical(ref, s)
            eff_cap = DEFAULT_BATCH_MAX if cap is None else cap
            batches = {}
            for rec in tr.records:
                batches.setdefault(rec[7], []).append(rec[0])
            for bid, tasks in batches.items():
                assert len(tasks) <= eff_cap
                for t in tasks:                 # independence: no pred
                    assert not set(g.pred[t]) & set(tasks)    # in-wave


def test_batch_ids_monotone_and_queue_order():
    g, tg = paper_spg(), paper_topology()
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    _, _, tr = inst.schedule_traced(q, 1.06, backend="scalar")
    bids = [rec[7] for rec in tr.records]
    assert bids == sorted(bids)
    assert [rec[0] for rec in tr.records] == list(q)
    assert max(Counter(bids).values()) > 1       # a real wave formed


def test_batch_zero_rejected():
    g, tg = paper_spg(), paper_topology()
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    with pytest.raises(ValueError, match="batch"):
        inst.schedule(q, backend="scalar", batch=0)
    with pytest.raises(ValueError, match="batch"):
        inst.schedule(q, backend="scalar", batch=2.5)
    with pytest.raises(ValueError, match="batch"):
        Scheduler(tg, batch=0)
    with pytest.raises(ValueError, match="batch"):
        # non-integral caps must not silently truncate to a different
        # cap (and plan-cache key) than the caller asked for
        Scheduler(tg).submit(g, batch=2.5)
    with pytest.raises(ValueError, match="batch"):
        # validated even under the reference engine: a bad per-call
        # value fails loudly instead of being silently ignored
        Scheduler(tg, engine="reference").submit(g, batch=0)


def test_batch_knob_threading_and_plan_cache():
    """Session default, per-call override, plan-cache key, and the
    reference engine's None; plans agree bit-for-bit across caps."""
    g, tg = paper_spg(), paper_topology()
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sched = Scheduler(tg, batch=4)
    p4 = sched.submit(g, policy)
    assert p4.batch == 4
    p1 = sched.submit(g, policy, batch=1)        # per-call override wins
    assert p1.batch == 1
    assert p1 is not p4                          # distinct cache entries
    assert_identical(p1.schedule, p4.schedule)
    keys = set(sched._sessions[id(g)].plans)
    assert {(policy, k[1], k[2]) for k in keys} >= {
        (policy, p4.backend, 4), (policy, p1.backend, 1)}
    assert sched.submit(g, policy) is p4         # cache hit, default cap
    pref = Scheduler(tg, engine="reference").submit(g, policy)
    assert pref.batch is None
    assert_identical(pref.schedule, p4.schedule)


def test_resume_mid_batch_cross_backend():
    """A resume position that splits a wave replays bit-identically —
    including across backends and batch caps (records are portable and
    batch ids only annotate)."""
    tg = paper_topology()
    g = random_spg(40, np.random.default_rng(11), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    ref, bref, tr = inst.schedule_traced(q, 0.5, backend="scalar")
    bids = [rec[7] for rec in tr.records]
    pos = next(k for k in range(1, len(bids)) if bids[k] == bids[k - 1])
    for backend, cap in (("scalar", None), ("vector", 1), ("vector", 3)):
        s, b, tr2 = inst.schedule_traced(q, 0.5, resume=tr, resume_pos=pos,
                                         backend=backend, batch=cap)
        assert_identical(ref, s)
        assert b == bref
        bids2 = [rec[7] for rec in tr2.records]
        assert bids2[:pos] == bids[:pos]         # prefix annotation kept
        assert bids2 == sorted(bids2)            # suffix renumbers monotone


# --------------------------------------------------- pallas: mode knobs
def test_interpret_and_mode_env_overrides(monkeypatch):
    """REPRO_PALLAS_INTERPRET=0/1 forces dispatch; dtype/tile defaults
    follow it (compiled -> f32 + tile-padded, interpreter -> f64 raw)
    and have their own overrides."""
    jax = pytest.importorskip("jax")
    from repro.core.backends import pallas as pb

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert pb._use_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert pb._use_interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert pb._use_interpret() == (jax.default_backend() != "tpu")

    assert pb._use_f32(interpret=False) is True
    assert pb._use_f32(interpret=True) is False
    assert pb._use_tile(interpret=False) is True
    assert pb._use_tile(interpret=True) is False
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "float32")
    assert pb._use_f32(interpret=True) is True
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "float64")
    assert pb._use_f32(interpret=False) is False
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "bf16")
    with pytest.raises(ValueError, match="REPRO_PALLAS_DTYPE"):
        pb._use_f32(interpret=True)
    monkeypatch.delenv("REPRO_PALLAS_DTYPE")
    monkeypatch.setenv("REPRO_PALLAS_TILE", "1")
    assert pb._use_tile(interpret=True) is True
    monkeypatch.setenv("REPRO_PALLAS_TILE", "0")
    assert pb._use_tile(interpret=False) is False
    monkeypatch.delenv("REPRO_PALLAS_TILE")

    # a backend built under forced-compiled mode is f32 with tile-padded
    # (sublane/lane multiple) dims — construction is lazy, no TPU needed
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    g, tg = paper_spg(), paper_topology()
    inst = CompiledInstance(g, tg)
    be = pb.PallasBackend(inst)
    assert be._interpret is False and be._f32 and be._tile
    assert be._Pp % pb.SUBLANE_F32 == 0 and be._Pp >= inst.P
    assert be._Lp % pb.LANE == 0 and be._Lp >= inst._n_links


def test_interpret_forced_on_runs_and_matches_scalar(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 runs end-to-end and stays
    decision-identical (it is the CI dispatch, forced explicitly)."""
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    g, tg = paper_spg(), paper_topology()
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    s = inst.schedule(q, alpha=1.06, backend="scalar")
    p = inst.schedule(q, alpha=1.06, backend="pallas")
    assert np.array_equal(s.proc, p.proc)
    assert np.array_equal(s.finish, p.finish)


def test_tile_padding_under_interpreter(monkeypatch):
    """Tile padding is arithmetic-neutral: forcing the Mosaic-shaped
    (sublane x lane padded) tensors under the interpreter changes no
    decision and no float."""
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_PALLAS_TILE", "1")
    from repro.core.backends import pallas as pb

    tg = paper_topology()
    g = random_spg(24, np.random.default_rng(4), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    be = inst.backend_instance("pallas")
    assert be._tile and be._Pp % pb.SUBLANE_F32 == 0 \
        and be._Lp % pb.LANE == 0
    s = inst.schedule(q, alpha=0.85, backend="scalar")
    p = inst.schedule(q, alpha=0.85, backend="pallas")
    assert np.array_equal(s.proc, p.proc)
    assert np.array_equal(s.finish, p.finish)


# ------------------------------------------------ pallas: O(levels) I/O
def test_roundtrips_scale_with_levels_not_decisions(monkeypatch):
    """Per-wave path: one launch/round-trip per wave — O(levels).  Scan
    path (the default): ONE launch, ONE state upload, ONE blocking
    fetch for the whole schedule — O(1), independent of levels."""
    pytest.importorskip("jax")
    tg = paper_topology()
    g = random_spg(40, np.random.default_rng(23), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    # expected waves: maximal independent runs of the queue, cap-split
    runs = 0
    qi = 0
    while qi < len(q):
        wave = set()
        while qi < len(q) and len(wave) < DEFAULT_BATCH_MAX \
                and not (set(g.pred[q[qi]]) & wave):
            wave.add(q[qi])
            qi += 1
        runs += 1
    be = inst.backend_instance("pallas")
    l0, r0, u0 = be.n_launches, be.n_roundtrips, be.n_state_uploads
    p = inst.schedule(q, alpha=0.85, backend="pallas")
    assert be.n_launches - l0 == 1
    assert be.n_roundtrips - r0 == 1
    assert be.n_state_uploads - u0 == 1
    monkeypatch.setenv("REPRO_PALLAS_SCAN", "0")
    l0, r0, u0 = be.n_launches, be.n_roundtrips, be.n_state_uploads
    pw = inst.schedule(q, alpha=0.85, backend="pallas")
    assert be.n_launches - l0 == runs
    assert be.n_roundtrips - r0 == runs
    assert be.n_state_uploads - u0 == 1          # one upload per run start
    # a wave per rank level (plus cap splits), not per decision
    n_levels = len(set(g.depth.tolist()))
    assert runs <= n_levels + 2
    assert runs < g.n // 2
    s = inst.schedule(q, alpha=0.85, backend="scalar")
    for sched in (p, pw):
        assert np.array_equal(s.proc, sched.proc)
        assert np.array_equal(s.finish, sched.finish)


# ------------------------------------------------ pallas: kernel cache
def test_kernel_cache_lru_eviction_changes_nothing(monkeypatch):
    """A capacity-1 kernel cache forces an eviction/rebuild on every
    shape switch; the rebuilt kernels produce identical schedules and
    the cache never exceeds its bound (per-wave path: the scan path has
    its own mirror of this test below)."""
    pytest.importorskip("jax")
    from repro.core.backends import pallas as pb

    monkeypatch.setenv("REPRO_PALLAS_SCAN", "0")
    monkeypatch.setattr(pb, "_RUN_CACHE_MAX", 1)
    pb._RUN_CACHE.clear()
    tg = paper_topology()
    cases = []
    for seed, n in ((1, 12), (2, 18)):
        g = random_spg(n, np.random.default_rng(seed), ccr=1.0, tg=tg,
                       outdeg_constraint=True)
        r, q = _queue_for(g, tg)
        cases.append((CompiledInstance(g, tg, rank=r), q))
    for _ in range(2):                           # alternate -> evict
        for inst, q in cases:
            s = inst.schedule(q, alpha=0.85, backend="scalar")
            p = inst.schedule(q, alpha=0.85, backend="pallas")
            assert np.array_equal(s.proc, p.proc)
            assert np.array_equal(s.finish, p.finish)
            assert len(pb._RUN_CACHE) <= 1


# ------------------------------------------- pallas: scan trace resume
def test_scan_trace_resumes_cross_backend():
    """Traces are portable across the scan boundary in both directions:
    a trace recorded through the whole-schedule scan dispatch replays
    decision-identically on scalar/vector, and a scalar trace resumes
    through the scan path — including a resume position that splits a
    wave, where the suffix re-enters the scan dispatch mid-schedule."""
    pytest.importorskip("jax")
    tg = paper_topology()
    g = random_spg(40, np.random.default_rng(31), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    ref, bref, tr_p = inst.schedule_traced(q, 0.5, backend="pallas")
    bids = [rec[7] for rec in tr_p.records]
    pos = next(k for k in range(1, len(bids)) if bids[k] == bids[k - 1])
    # scan-recorded -> scalar/vector replay
    for backend in ("scalar", "vector"):
        s, b, _ = inst.schedule_traced(q, 0.5, resume=tr_p,
                                       resume_pos=pos, backend=backend)
        assert_identical(ref, s)
        assert b == bref
    # scalar-recorded -> scan replay; the replayed suffix is ONE dispatch
    sref, bs, tr_s = inst.schedule_traced(q, 0.5, backend="scalar")
    assert_identical(ref, sref)
    be = inst.backend_instance("pallas")
    l0, u0 = be.n_launches, be.n_state_uploads
    p, b, _ = inst.schedule_traced(q, 0.5, resume=tr_s, resume_pos=pos,
                                   backend="pallas")
    assert_identical(ref, p)
    assert b == bs
    assert be.n_launches - l0 == 1
    assert be.n_state_uploads - u0 == 1


def test_update_suffix_replay_reenters_scan_path():
    """A mid-schedule drift update on a pallas session replays only the
    trace suffix — through the scan dispatch — and stays bit-identical
    to a scalar session applying the same drift."""
    pytest.importorskip("jax")
    tg = paper_topology()
    g = random_spg(40, np.random.default_rng(13), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    pol = HVLB_CC_B(alpha_max=1.0, alpha_step=0.5)
    sp = Scheduler(tg, policy=pol, backend="pallas")
    ss = Scheduler(tg, policy=pol, backend="scalar")
    p0, s0 = sp.submit(g), ss.submit(g)
    assert p0.fallback is None
    assert_identical(p0.schedule, s0.schedule)
    task = int(np.argmax(p0.schedule.start))     # a late task: real suffix
    up = sp.update(task_rates={task: 1.4})
    us = ss.update(task_rates={task: 1.4})
    assert up.fallback is None
    assert_identical(up.schedule, us.schedule)
    assert up.replay.suffix_start == us.replay.suffix_start
    if up.replay.suffix_start > 0:               # replay really happened
        assert up.replay.decisions_replayed > 0


# ----------------------------------------------- pallas: scan run cache
def test_scan_cache_lru_eviction_changes_nothing(monkeypatch):
    """Scan-path mirror of the kernel-cache test: a capacity-1 cache
    forces an eviction/rebuild of the compiled whole-schedule scan on
    every padded-shape switch; the rebuilt scans produce identical
    schedules and the cache never exceeds its bound."""
    pytest.importorskip("jax")
    from repro.core.backends import pallas as pb

    monkeypatch.setattr(pb, "_RUN_CACHE_MAX", 1)
    pb._RUN_CACHE.clear()
    tg = paper_topology()
    cases = []
    for seed, n in ((1, 12), (2, 40)):           # Np buckets 16 vs 64
        g = random_spg(n, np.random.default_rng(seed), ccr=1.0, tg=tg,
                       outdeg_constraint=True)
        r, q = _queue_for(g, tg)
        cases.append((CompiledInstance(g, tg, rank=r), q))
    keys = set()
    for _ in range(2):                           # alternate -> evict
        for inst, q in cases:
            s = inst.schedule(q, alpha=0.85, backend="scalar")
            p = inst.schedule(q, alpha=0.85, backend="pallas")
            assert np.array_equal(s.proc, p.proc)
            assert np.array_equal(s.finish, p.finish)
            assert len(pb._RUN_CACHE) <= 1
            keys |= set(pb._RUN_CACHE)
    assert all(k[0] == "scan" for k in keys)
    assert len(keys) == 2                        # the shapes really differ


def test_scan_cache_keys_on_padded_shape_not_graph():
    """The scan cache keys on PADDED dims only, so instances whose
    graphs bucket to the same shapes share ONE compiled scan."""
    pytest.importorskip("jax")
    from repro.core.backends import pallas as pb

    pb._RUN_CACHE.clear()
    tg = paper_topology()
    for inst_seed in (3, 3):                     # two instances, same graph
        g = random_spg(20, np.random.default_rng(inst_seed), ccr=1.0,
                       tg=tg, outdeg_constraint=True)
        r, q = _queue_for(g, tg)
        inst = CompiledInstance(g, tg, rank=r)
        s = inst.schedule(q, alpha=0.85, backend="scalar")
        p = inst.schedule(q, alpha=0.85, backend="pallas")
        assert np.array_equal(s.proc, p.proc)
    scan_keys = [k for k in pb._RUN_CACHE if k[0] == "scan"]
    assert len(scan_keys) == 1                   # second instance: cache hit


# ------------------------------------------- pallas: f32 near-tie policy
def _two_proc_tie_case(d: float):
    """One exit task whose candidate selection values are exactly
    ``(1.0, 1.0 + d, 2.0)`` (explicit comp matrix; exit tasks select on
    bare EFT, and EST = 0 on an empty machine — so the kernel's argmin
    sees exactly these values)."""
    tg = fully_switched_topology(3, rates=np.ones(3),
                                 link_speeds=np.ones(3))
    g = SPG(n=1, edges=[], weights=np.array([1.0]),
            comp_matrix=np.array([[1.0, 1.0 + d, 2.0]]))
    return g, tg


@pytest.mark.parametrize("mag", [1e-10, 1e-8, 3e-7, 1e-6, 1e-4, 1e-2])
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_f32_near_tie_fuzz(monkeypatch, mag, sign):
    """Fuzz candidate values across the f32 near-tie boundary: above
    ``F32_NEAR_TIE_RTOL`` the f32 winner matches the f64 scalar
    reference; below it the winner is pinned to the deterministic
    f32-lexicographic argmin (first index on exact f32 ties)."""
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "float32")
    from repro.core.backends.pallas import F32_NEAR_TIE_RTOL

    d = sign * mag
    g, tg = _two_proc_tie_case(d)
    inst = CompiledInstance(g, tg)
    scalar_win = int(inst.schedule([0], backend="scalar").proc[0])
    assert scalar_win == (1 if d < 0 else 0)     # f64 reference
    pallas_win = int(inst.schedule([0], backend="pallas").proc[0])
    # the pinned deterministic policy: f32 argmin, first index on ties
    v0, v1 = np.float32(1.0), np.float32(1.0 + d)
    predicted = 1 if v1 < v0 else 0
    assert pallas_win == predicted
    if mag >= F32_NEAR_TIE_RTOL:
        # outside the documented band f32 must agree with the reference
        assert pallas_win == scalar_win
    # deterministic: a fresh instance reproduces the winner exactly
    assert int(CompiledInstance(*_two_proc_tie_case(d)).schedule(
        [0], backend="pallas").proc[0]) == pallas_win


def test_scan_f32_tile_matches_wave_and_policy(monkeypatch):
    """The scan path under compiled-path numerics (f32 + tile padding —
    the configuration a dedicated CI step forces): decisions identical
    to the per-wave f32 path and to the f64 scalar reference on a
    well-separated workload, floats within the documented tolerance."""
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "float32")
    monkeypatch.setenv("REPRO_PALLAS_TILE", "1")
    from repro.core.backends.pallas import F32_NEAR_TIE_RTOL

    tg = paper_topology()
    g = random_spg(30, np.random.default_rng(6), ccr=1.0, tg=tg,
                   outdeg_constraint=True)
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    be = inst.backend_instance("pallas")
    assert be._f32 and be._tile
    s = inst.schedule(q, alpha=0.85, backend="scalar")
    p_scan = inst.schedule(q, alpha=0.85, backend="pallas")
    monkeypatch.setenv("REPRO_PALLAS_SCAN", "0")
    p_wave = inst.schedule(q, alpha=0.85, backend="pallas")
    assert np.array_equal(p_scan.proc, p_wave.proc)
    assert np.array_equal(p_scan.finish, p_wave.finish)
    assert np.array_equal(p_scan.proc, s.proc)
    np.testing.assert_allclose(p_scan.finish, s.finish,
                               rtol=F32_NEAR_TIE_RTOL)


def test_f32_schedule_deterministic_and_close(monkeypatch):
    """Whole-schedule f32 run: deterministic across fresh instances,
    decision-identical to scalar on a generic (well-separated) workload,
    floats within the documented tolerance."""
    pytest.importorskip("jax")
    monkeypatch.setenv("REPRO_PALLAS_DTYPE", "float32")
    from repro.core.backends.pallas import F32_NEAR_TIE_RTOL

    g, tg = paper_spg(), paper_topology()
    r, q = _queue_for(g, tg)
    inst = CompiledInstance(g, tg, rank=r)
    be = inst.backend_instance("pallas")
    assert be._f32
    s = inst.schedule(q, alpha=1.06, backend="scalar")
    p = inst.schedule(q, alpha=1.06, backend="pallas")
    assert np.array_equal(s.proc, p.proc)
    np.testing.assert_allclose(p.finish, s.finish,
                               rtol=F32_NEAR_TIE_RTOL)
    p2 = CompiledInstance(g, tg, rank=r).schedule(q, alpha=1.06,
                                                  backend="pallas")
    assert np.array_equal(p.proc, p2.proc)
    assert np.array_equal(p.finish, p2.finish)
