"""Chaos harness: seeded random fault scripts judged by the independent
validator (DESIGN.md §6).

Each script drives one long-lived :class:`Scheduler` session through a
random sequence of fault events — processor/link failures, link/compute
degradation, rate drift, restores — and after every replan asserts:

  * the schedule is clean under :func:`schedule_violations` (the oracle
    re-derives precedence, processor/link exclusivity, route feasibility
    and fault avoidance from the placements alone);
  * the fault-invalidation counters are consistent
    (``invalidated_by_fault == n - suffix_start``);
  * the only exceptions that ever escape are the *typed* ones —
    :class:`InfeasibleScheduleError` when no feasible placement remains,
    and the spec-level ``ValueError`` for killing the last processor.

A subset of scripts additionally checks the replanned schedule
bit-exactly against a fresh scheduler started with the final fault set
(the suffix-replay soundness oracle).

Seeds come from ``REPRO_CHAOS_SEEDS`` (either ``"a:b"`` for a range or a
comma list) so CI can matrix a fixed set per backend; the default is 104
scripts, trimmed when the resolved backend is pallas (interpreted mode).
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_B, HVLB_CC_IC,
                        InfeasibleScheduleError, LinkDegraded, LinkDown,
                        ProcessorDown, Scheduler, fully_switched_topology,
                        paper_topology, random_spg, resolve_backend_name,
                        schedule_violations)


def _seed_list():
    env = os.environ.get("REPRO_CHAOS_SEEDS")
    if env:
        if ":" in env:
            a, b = env.split(":")
            return list(range(int(a), int(b)))
        return [int(s) for s in env.split(",") if s.strip()]
    try:
        tg = paper_topology()
        backend = resolve_backend_name(None, tg.n_procs, tg)
    except Exception:
        backend = "scalar"
    return list(range(24)) if backend == "pallas" else list(range(104))


SEEDS = _seed_list()

_POLICIES = (
    lambda: HVLB_CC_B(alpha_max=1.0, alpha_step=0.5),
    lambda: HVLB_CC_IC(alpha_max=1.0, alpha_step=0.5),
    lambda: HSV_CC(),
)


def _random_case(rng):
    if rng.random() < 0.5:
        tg = paper_topology()
    else:
        P = int(rng.integers(3, 6))
        tg = fully_switched_topology(
            P, rates=(0.6 + rng.random(P)).tolist(),
            link_speeds=(0.8 + 2.0 * rng.random(P)).tolist())
    n = int(rng.integers(10, 18))
    g = random_spg(n, rng, ccr=float(rng.choice([0.5, 1.0, 2.0])),
                   tg=tg, outdeg_constraint=True)
    pol = _POLICIES[int(rng.integers(len(_POLICIES)))]()
    return tg, g, pol


def _spec_as_faults(spec):
    faults = [ProcessorDown(p) for p in spec.down_procs]
    for l, f in spec.link_factors:
        faults.append(LinkDown(l) if math.isinf(f) else LinkDegraded(l, f))
    return tuple(faults)


def _assert_plan_ok(plan, sched, g):
    assert plan is not None
    v = schedule_violations(plan.schedule, sched.faults)
    assert v == [], v
    r = plan.replay
    assert 0 <= r.suffix_start <= g.n
    assert r.invalidated_by_fault == g.n - r.suffix_start \
        or r.invalidated_by_fault == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_script(seed):
    rng = np.random.default_rng(100_000 + seed)
    tg, g, pol = _random_case(rng)
    links = tg.all_links()
    sched = Scheduler(tg, policy=pol)
    plan = sched.submit(g)
    assert schedule_violations(plan.schedule, sched.faults) == []

    drifted = False            # task-rate drift breaks the fresh oracle
    for _ in range(int(rng.integers(3, 7))):
        op = rng.choice(["proc_down", "link_down", "link_degrade",
                         "task_spike", "drift", "restore"])
        try:
            if op == "proc_down":
                up = [p for p in range(tg.n_procs)
                      if p not in sched.faults.down_procs]
                plan = sched.mark_failed(proc=int(rng.choice(up)))
            elif op == "link_down":
                plan = sched.mark_failed(link=str(rng.choice(links)))
            elif op == "link_degrade":
                plan = sched.degrade(link=str(rng.choice(links)),
                                     factor=float(rng.choice([1.5, 2., 4.])))
            elif op == "task_spike":
                plan = sched.degrade(task=int(rng.integers(g.n)),
                                     factor=float(rng.choice([1.5, 3.0])))
                drifted = True
            elif op == "drift":
                tr = {int(t): float(0.5 + rng.random())
                      for t in rng.choice(g.n, size=3, replace=False)}
                plan = sched.update(task_rates=tr)
                drifted = True
            else:                                   # restore
                spec = sched.faults
                if spec.down_procs and (rng.random() < 0.5
                                        or not spec.link_factors):
                    plan = sched.restore(
                        proc=int(rng.choice(spec.down_procs)))
                elif spec.link_factors:
                    plan = sched.restore(
                        link=str(rng.choice([l for l, _ in
                                             spec.link_factors])))
                else:
                    continue                        # nothing to restore
        except InfeasibleScheduleError:
            return                                  # typed, expected
        except ValueError as e:
            # killing the last processor is rejected at the spec level
            assert "every processor marked down" in str(e)
            return
        _assert_plan_ok(plan, sched, g)

    # ---- fresh-scheduler oracle: the incrementally replanned schedule
    # must be bit-identical to planning from scratch under the same
    # faults (rate drift changes the graph, so skip those scripts).
    if drifted or sched.faults.is_empty:
        return
    fresh_pol = plan.policy
    if any(f.name == "period" for f in dataclasses.fields(fresh_pol)):
        fresh_pol = dataclasses.replace(fresh_pol, period=plan.period)
    fresh = Scheduler(tg, policy=fresh_pol,
                      faults=_spec_as_faults(sched.faults))
    try:
        ref = fresh.submit(g)
    except InfeasibleScheduleError:
        pytest.fail("incremental replan succeeded where a fresh plan "
                    "is infeasible")
    assert np.array_equal(plan.schedule.proc, ref.schedule.proc)
    assert np.array_equal(plan.schedule.start, ref.schedule.start)
    assert np.array_equal(plan.schedule.finish, ref.schedule.finish)


# ---------------------------------------------------------------------
# Targeted fault-replay semantics (deterministic)
# ---------------------------------------------------------------------
def _case(seed=0, n=20):
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(n, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    return tg, g


def test_unused_proc_fault_keeps_whole_trace():
    """Failing a processor the plan never used invalidates nothing and
    leaves the schedule bit-identical."""
    rng = np.random.default_rng(3)
    # one crippled processor (tiny rate => huge comp) the plan avoids
    tg = fully_switched_topology(4, rates=[1.0, 1.1, 0.9, 1e-6],
                                 link_speeds=[1.0, 2.0, 1.5, 1.0])
    g = random_spg(16, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    p0 = sched.submit(g)
    assert 3 not in set(p0.schedule.proc.tolist())
    p1 = sched.mark_failed(proc=3)
    assert p1.replay.invalidated_by_fault == 0
    assert p1.replay.suffix_start == g.n
    assert np.array_equal(p0.schedule.proc, p1.schedule.proc)
    assert np.array_equal(p0.schedule.start, p1.schedule.start)
    assert np.array_equal(p0.schedule.finish, p1.schedule.finish)


def test_used_proc_fault_invalidates_suffix_only():
    tg, g = _case(0)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    p0 = sched.submit(g)
    victim = int(p0.schedule.proc[np.argmin(p0.schedule.start)])
    p1 = sched.mark_failed(proc=victim)
    assert victim not in set(p1.schedule.proc.tolist())
    assert p1.replay.invalidated_by_fault == g.n - p1.replay.suffix_start
    assert p1.replay.invalidated_by_fault > 0
    assert schedule_violations(p1.schedule, sched.faults) == []


def test_unused_link_degrade_keeps_whole_trace():
    rng = np.random.default_rng(3)
    # proc 4 is crippled => its star link l4 never carries a message
    tg = fully_switched_topology(4, rates=[1.0, 1.1, 0.9, 1e-6],
                                 link_speeds=[1.0, 2.0, 1.5, 1.0])
    g = random_spg(16, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    p0 = sched.submit(g)
    used = {l for m in p0.schedule.messages.values()
            for (l, _s, _f) in m.intervals}
    assert "l4" not in used
    p1 = sched.degrade(link="l4", factor=4.0)
    assert p1.replay.invalidated_by_fault == 0
    assert np.array_equal(p0.schedule.proc, p1.schedule.proc)
    assert np.array_equal(p0.schedule.start, p1.schedule.start)


def test_restore_returns_to_healthy_plan():
    tg, g = _case(1)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    p0 = sched.submit(g)
    sched.mark_failed(proc=1)
    p2 = sched.restore(proc=1)
    assert sched.faults.is_empty
    assert np.array_equal(p0.schedule.proc, p2.schedule.proc)
    assert np.array_equal(p0.schedule.start, p2.schedule.start)
    assert np.array_equal(p0.schedule.finish, p2.schedule.finish)


def test_kill_last_processor_rejected():
    tg, g = _case(2)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    sched.submit(g)
    sched.mark_failed(proc=0)
    sched.mark_failed(proc=1)
    with pytest.raises(ValueError, match="every processor marked down"):
        sched.mark_failed(proc=2)


def test_fault_before_submit_is_recorded():
    tg, g = _case(4)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    assert sched.mark_failed(proc=2) is None     # nothing to replan yet
    plan = sched.submit(g)
    assert 2 not in set(plan.schedule.proc.tolist())
    assert schedule_violations(plan.schedule, sched.faults) == []


def test_scheduler_faults_argument():
    tg, g = _case(5)
    a = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5),
                  faults=(ProcessorDown(0),))
    pa = a.submit(g)
    b = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    b.submit(g)
    pb = b.mark_failed(proc=0)
    assert np.array_equal(pa.schedule.proc, pb.schedule.proc)
    assert np.array_equal(pa.schedule.start, pb.schedule.start)


def test_partition_raises_infeasible():
    """Committed prefix on both sides of a link partition => the join
    task has no feasible candidate and the engine raises the typed
    error instead of scheduling through a dead link."""
    tg = fully_switched_topology(2, rates=[1.0, 1.0],
                                 link_speeds=[1.0, 1.0])
    from repro.core.graph import SPG
    # two entries (balance splits them across the processors), one join
    g = SPG(n=3, edges=[(0, 2), (1, 2)], weights=[4.0, 4.0, 2.0],
            tpl={(0, 2): 2.0, (1, 2): 2.0})
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=1.0))
    p0 = sched.submit(g)
    if len(set(p0.schedule.proc[:2].tolist())) < 2:
        pytest.skip("entries co-located; no partition to exercise")
    with pytest.raises(InfeasibleScheduleError) as ei:
        sched.mark_failed(link="l1")
    assert ei.value.task == 2
    # the infeasible fault stays recorded; a fresh submit re-raises
    with pytest.raises(InfeasibleScheduleError):
        sched.submit(g)


def test_compute_spike_rides_update_path():
    tg, g = _case(6)
    sched = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    sched.submit(g)
    plan = sched.degrade(task=int(g.topo_order[-1]), factor=2.0)
    assert plan.replay.invalidated_by_fault == \
        g.n - plan.replay.suffix_start
    assert schedule_violations(plan.schedule, sched.faults) == []
