"""Session-boundary input validation: bad input fails with an actionable
one-line ``ValueError`` at ``submit``/``update``/``submit_many``/
``Scheduler()`` time instead of a deep engine or NumPy stack trace."""
import math

import numpy as np
import pytest

from repro.core import (HVLB_CC_B, Scheduler, fully_switched_topology,
                        paper_topology, random_spg)
from repro.core.graph import SPG


def _sched():
    tg = paper_topology()
    rng = np.random.default_rng(0)
    g = random_spg(12, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
    s = Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))
    return s, g


# ---------------------------------------------------------------- rates
@pytest.mark.parametrize("bad", [float("nan"), 0.0, -1.0, float("inf"),
                                 "fast", None])
def test_update_rejects_bad_rate_factor(bad):
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="task_rates"):
        s.update(task_rates={0: bad})


@pytest.mark.parametrize("tid", [-1, 99, 3.5, "t3", None, True])
def test_update_rejects_unknown_task_id(tid):
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="unknown task id"):
        s.update(task_rates={tid: 1.5})


def test_probe_update_validates_too():
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="unknown task id"):
        s.probe_update(task_rates={g.n: 1.5})


def test_degrade_task_rejects_bad_factor():
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="task_rates"):
        s.degrade(task=0, factor=float("nan"))


# ---------------------------------------------------------------- links
@pytest.mark.parametrize("bad", [float("nan"), 0.0, -2.0])
def test_update_rejects_bad_link_speed(bad):
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="link_speed"):
        s.update(link_speed={"l1": bad})


def test_update_rejects_unknown_link():
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="unknown links"):
        s.update(link_speed={"l99": 1.0})


def test_fault_api_rejects_unknown_resources():
    s, g = _sched()
    s.submit(g)
    with pytest.raises(ValueError, match="unknown link"):
        s.mark_failed(link="l99")
    with pytest.raises(ValueError, match="out of range"):
        s.mark_failed(proc=7)
    with pytest.raises(ValueError, match="finite positive"):
        s.degrade(link="l1", factor=-2.0)
    with pytest.raises(ValueError):
        s.mark_failed()                  # exactly one resource required
    with pytest.raises(ValueError):
        s.mark_failed(proc=0, link="l1")


# ---------------------------------------------------------------- graphs
def test_submit_rejects_non_graph():
    s, _ = _sched()
    with pytest.raises(ValueError, match="expects an SPG"):
        s.submit("not a graph")


def test_submit_rejects_nan_weights():
    s, _ = _sched()
    g = SPG(n=3, edges=[(0, 1), (1, 2)], weights=[1.0, float("nan"), 2.0],
            tpl={(0, 1): 1.0, (1, 2): 1.0})
    with pytest.raises(ValueError, match="NaN"):
        s.submit(g)


def test_submit_rejects_negative_weights():
    s, _ = _sched()
    g = SPG(n=2, edges=[(0, 1)], weights=[1.0, -3.0], tpl={(0, 1): 1.0})
    with pytest.raises(ValueError, match="finite and >= 0"):
        s.submit(g)


def test_submit_rejects_cyclic_graph():
    s, _ = _sched()
    g = SPG(n=2, edges=[(0, 1)], weights=[1.0, 1.0], tpl={(0, 1): 1.0})
    g.edges.append((1, 0))               # mutate behind __post_init__
    g.succ[1] = [0]
    g.pred[0] = [1]
    g._topo = []                         # what a re-toposort would find
    with pytest.raises(ValueError, match="cyclic"):
        s.submit(g)


# ------------------------------------------------------------- topology
def test_scheduler_rejects_bad_topology_rates():
    tg = fully_switched_topology(3, rates=[1.0, 0.0, 1.0],
                                 link_speeds=[1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="processor rates"):
        Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))


def test_scheduler_rejects_bad_topology_link_speed():
    tg = fully_switched_topology(3, rates=[1.0, 1.0, 1.0],
                                 link_speeds=[1.0, math.nan, 1.0])
    with pytest.raises(ValueError, match="link speed"):
        Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))


def test_scheduler_rejects_route_with_unknown_link():
    # Topology.__post_init__ itself chokes on a route naming an unknown
    # link, so build a consistent one and lose the link afterwards (a
    # hand-mutated table) — check_topology still gives the one-liner.
    tg = fully_switched_topology(2, rates=[1.0, 1.0],
                                 link_speeds=[1.0, 1.0])
    del tg.link_speed["l2"]
    with pytest.raises(ValueError, match="unknown links"):
        Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5))


def test_wave_timeout_must_be_positive():
    tg = paper_topology()
    with pytest.raises(ValueError, match="wave_timeout"):
        Scheduler(tg, policy=HVLB_CC_B(alpha_max=1.0, alpha_step=0.5),
                  wave_timeout=0.0)
