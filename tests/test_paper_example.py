"""Pin every number the paper states for its worked example (Figs. 2-6,
Tables 1-4) — the faithful-reproduction anchor tests."""
import numpy as np
import pytest

from repro.core import (PAPER_COMP_EXP5, paper_spg, paper_topology,
                        schedule_holes, schedule_hsv_cc, schedule_hvlb_cc)
from repro.core.ranks import (hprv_a, hprv_b, hrank, priority_queue,
                              rank_matrix)

# shims called deliberately; their warning is pinned by
# tests/test_deprecation.py (keeps -W error::DeprecationWarning clean)
pytestmark = pytest.mark.filterwarnings(
    "ignore:schedule_h:DeprecationWarning")

# Table 2 of the paper (rank per processor, hrank).
TABLE2_RANK_P1 = [145.0, 133.0, 109.0, 109.0, 85.0, 50.0, 67.0, 48.0, 20.0, 15.0]
TABLE2_RANK_P2 = [81.66, 74.99, 61.66, 61.66, 48.33, 29.67, 38.33, 28.0, 13.0, 10.0]
TABLE2_RANK_P3 = [96.99, 90.33, 73.67, 73.67, 57.0, 36.0, 45.33, 34.33, 16.0, 12.0]
TABLE2_HRANK = [107.9, 99.4, 81.4, 81.4, 63.4, 38.6, 50.2, 36.8, 16.3, 12.3]
TABLE2_DEPTH = [1, 1, 1, 2, 2, 2, 3, 3, 4, 4]
TABLE2_OUTD = [2, 2, 2, 2, 2, 1, 1, 1, 0, 0]


@pytest.fixture(scope="module")
def case():
    g = paper_spg()
    tg = paper_topology()
    return g, tg


def test_route_speeds_table3(case):
    _, tg = case
    assert tg.route_speed(0, 1) == 1.0
    assert tg.route_speed(0, 2) == 1.0
    assert tg.route_speed(1, 2) == 2.0
    # symmetric
    assert tg.route_speed(2, 1) == 2.0


def test_processor_transfer_speeds(case):
    _, tg = case
    assert tg.proc_speed(0) == pytest.approx(1.0)
    assert tg.proc_speed(1) == pytest.approx(1.5)
    assert tg.proc_speed(2) == pytest.approx(1.5)


def test_computation_times_table1(case):
    g, tg = case
    assert g.comp(5, 0, tg.rates) == 15   # n6 on p1
    assert g.comp(5, 1, tg.rates) == 10
    assert g.comp(5, 2, tg.rates) == 12


def test_depth_and_outdegree(case):
    g, _ = case
    assert list(g.depth) == TABLE2_DEPTH
    assert [g.outd(i) for i in range(10)] == TABLE2_OUTD
    assert sorted(g.pred[4]) == [0, 1, 2]       # pred(n5) = {n1,n2,n3}
    assert sorted(g.succ[4]) == [6, 7]          # succ(n5) = {n7,n8}


def test_rank_matrix_table2(case):
    g, tg = case
    r = rank_matrix(g, tg)
    np.testing.assert_allclose(r[:, 0], TABLE2_RANK_P1, atol=0.02)
    np.testing.assert_allclose(r[:, 1], TABLE2_RANK_P2, atol=0.02)
    np.testing.assert_allclose(r[:, 2], TABLE2_RANK_P3, atol=0.02)
    np.testing.assert_allclose(r.mean(1), TABLE2_HRANK, atol=0.06)


def test_priority_queues_section43(case):
    g, tg = case
    r = rank_matrix(g, tg)
    h = r.mean(1)
    qa = [i + 1 for i in priority_queue(hprv_a(g, tg, r), h)]
    qb = [i + 1 for i in priority_queue(hprv_b(g, tg, r), h)]
    assert qa == [1, 2, 3, 4, 5, 7, 6, 8, 9, 10]
    assert qb == [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]


def test_hsv_cc_makespan_73_fig4(case):
    g, tg = case
    s = schedule_hsv_cc(g, tg)
    s.validate()
    assert s.makespan == pytest.approx(73.0)
    # Section 3.1: p1 unused; 6 tasks on p2, 4 on p3.
    assert len(s.tasks_on(0)) == 0
    assert len(s.tasks_on(1)) == 6
    assert len(s.tasks_on(2)) == 4
    # Section 3.1: l2 and l4 only carry the n3 -> n6 message.
    ivs = s.link_intervals()
    assert [e for (_, _, e) in ivs.get("l2", [])] == [(2, 5)]
    assert [e for (_, _, e) in ivs.get("l4", [])] == [(2, 5)]
    assert "l1" not in ivs                      # l1 never used


@pytest.mark.parametrize("variant", ["A", "B"])
def test_hvlb_cc_makespan_62_fig6(case, variant):
    g, tg = case
    res = schedule_hvlb_cc(g, tg, variant=variant, alpha_max=3.0,
                           period=150.0)
    res.best.validate()
    assert res.best.makespan == pytest.approx(62.0)
    # all three processors are used (the LB improvement of Fig. 6)
    assert all(len(res.best.tasks_on(p)) > 0 for p in range(3))


def test_hvlb_b_alpha_window_fig5(case):
    """Fig. 5: HVLB_CC (B) reaches 62 exactly for alpha in [1.06, 1.10]
    and gives 71 at alpha = 0 (period = 150 reproduces the paper's axis)."""
    g, tg = case
    res = schedule_hvlb_cc(g, tg, variant="B", alpha_max=3.0, period=150.0)
    curve = dict(zip(np.round(res.alphas, 2).tolist(),
                     res.makespans.tolist()))
    assert curve[0.0] == pytest.approx(71.0)
    for a in (1.06, 1.08, 1.10):
        assert curve[a] == pytest.approx(62.0)
    assert curve[1.05] != pytest.approx(62.0)
    assert curve[1.11] != pytest.approx(62.0)


def test_hvlb_a_alpha_zero_is_hsv(case):
    g, tg = case
    res = schedule_hvlb_cc(g, tg, variant="A", alpha_max=0.0, period=150.0)
    assert res.best.makespan == pytest.approx(73.0)   # == HSV_CC


def test_exp5_schedule_holes():
    """Experiment 5 (Table 4): the hole search finds exploitable idle slots.

    Paper quotes holes 9/5/12 for n2/n5/n8 from its (unpublished) Exp-5
    Gantt; under our validated timing model the best HVLB_CC schedule has
    holes after n1 (11) and n8 (9) — pinned here, deviation documented in
    DESIGN.md §9.  The qualitative claim (holes exist and absorb optional
    parts) is what Experiment 5's benchmark reproduces.
    """
    g = paper_spg(comp=PAPER_COMP_EXP5)
    tg = paper_topology()
    res = schedule_hvlb_cc(g, tg, variant="B", alpha_max=3.0, period=150.0)
    holes = schedule_holes(res.best)
    assert holes, "best schedule must expose schedule holes"
    assert holes.get(0, 0.0) == pytest.approx(11.0)
    assert holes.get(7, 0.0) == pytest.approx(9.0)
