"""Checkpointing (incl. elastic resharding), data pipeline determinism,
gradient compression, planner placement, serving engine."""
import os
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import SHAPES, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.params import init_params
from repro.optim import AdamWConfig, compress_grads, decompress_grads
from repro.optim.adamw import init_opt_state
from repro.planner import (model_stage_graph, pipeline_graph,
                           plan_placement, serving_query_graph,
                           tpu_slice_topology)
from repro.planner.placement import replan
from repro.train import make_train_step


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    save(str(tmp_path), 7, {"params": params, "opt": opt})
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_publish(tmp_path):
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    save(str(tmp_path), 1, {"params": params})
    save(str(tmp_path), 2, {"params": params})
    # a stale temp dir must never be picked up
    (tmp_path / ".tmp_step_3").mkdir()
    assert latest_step(str(tmp_path)) == 2


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto an explicit 1-device mesh sharding
    (the resharding path used for elastic scale-up/down)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    save(str(tmp_path), 1, {"params": params})
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    back = restore(str(tmp_path), 1, {"params": params},
                   shardings={"params": sh})
    leaf = jax.tree.leaves(back["params"])[0]
    assert leaf.sharding.mesh.shape["data"] == 1


def test_train_restart_exact(tmp_path):
    """Crash/restart: N steps straight == k steps + restore + N-k steps."""
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, vocab=128)
    shape = ShapeConfig("t", 32, 2, "train")
    pipe = SyntheticTokenPipeline(cfg, shape)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=2,
                                                       total_steps=6)))

    def run(params, opt, start, stop):
        for s in range(start, stop):
            batch = pipe.device_batch(s)
            params, opt, info = step_fn(params, opt, batch)
        return params, opt, info

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    o0 = init_opt_state(p0)
    pa, oa, ia = run(p0, o0, 0, 6)

    p1 = init_params(cfg, jax.random.PRNGKey(0))
    o1 = init_opt_state(p1)
    p1, o1, _ = run(p1, o1, 0, 3)
    save(str(tmp_path), 3, {"params": p1, "opt": o1})
    st = restore(str(tmp_path), 3, {"params": p1, "opt": o1})
    pb, ob, ib = run(st["params"], st["opt"], 3, 6)
    np.testing.assert_allclose(float(ia["loss"]), float(ib["loss"]),
                               rtol=1e-5)


def test_data_pipeline_deterministic():
    cfg = reduced_config(get_arch("qwen3-8b"))
    shape = ShapeConfig("t", 64, 4, "train")
    pipe = SyntheticTokenPipeline(cfg, shape, DataConfig(seed=42))
    a = pipe.batch_for_step(5)
    b = pipe.batch_for_step(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch_for_step(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_gradient_compression_error_feedback():
    g = {"w": jnp.array([0.5, -1.0, 0.25, 3.0]),
         "b": jnp.array([1e-3, -1e-3])}
    qi, sc, res = compress_grads(g)
    deq = decompress_grads(qi, sc)
    for k in g:
        np.testing.assert_allclose(np.asarray(deq[k]), np.asarray(g[k]),
                                   atol=float(np.max(np.abs(g[k]))) / 100)
    # residual carries the quantization error exactly
    for k in g:
        np.testing.assert_allclose(np.asarray(g[k] - deq[k]),
                                   np.asarray(res[k]), atol=1e-7)


def test_planner_pipeline_balances_and_avoids_straggler():
    cfg = get_arch("qwen3-8b")
    g = pipeline_graph(cfg, SHAPES["train_4k"], n_microbatches=8)
    tg = tpu_slice_topology(n_slices=8, chips_per_slice=32, pods=2)
    plan = plan_placement(g, tg, "hvlb_b")
    assert len(plan.stage_map) == 8                  # all slices used
    assert plan.load_balance < 1.2
    tg_bad = tpu_slice_topology(n_slices=8, chips_per_slice=32, pods=2,
                                degraded={3: 0.5})
    plan2 = replan(g, tg_bad, [r for r in tg_bad.rates], "hvlb_b")
    # the degraded slice receives less work than healthy slices
    loads = plan2.schedule.proc_loads()
    assert loads[3] <= loads.max()


def test_planner_dsms_graph_needs_hvlb_b():
    """HSV_CC fails on the multi-query serving SPG; HVLB_CC (B) plans it."""
    from repro.core.scheduler import SchedulingFailure
    cfg = get_arch("zamba2-2.7b")
    q = serving_query_graph(cfg, SHAPES["decode_32k"], n_queries=3)
    tg = tpu_slice_topology(n_slices=8, chips_per_slice=32, pods=2)
    with pytest.raises(SchedulingFailure):
        plan_placement(q, tg, "hsv")
    plan = plan_placement(q, tg, "hvlb_b")
    plan.schedule.validate()
