"""Edge cases of imprecise.schedule_holes (Eqs. 20-21).

Covers the two paths the paper-example test cannot reach:
  * an exit task with *no* bounds at all — no later task on its
    processor, no successors — whose hole is unbounded,
  * a cross-processor successor whose message re-timing (LST'', Eq. 21)
    is capped by a rival message queued behind it on the route's link,
    not by the successor's start time.

Schedules are hand-built so every timing quantity is exact by
construction.
"""
import numpy as np
import pytest

from repro.core import SPG, Schedule, Topology, precision, schedule_holes
from repro.core.scheduler import MessagePlacement


def _two_proc_topology():
    """p0 -L- p1: a single contended link."""
    return Topology(proc_names=["p0", "p1"], rates=np.array([1.0, 1.0]),
                    link_speed={"L": 1.0}, routes={(0, 1): [("L",)]})


# ------------------------------------------------------- unbounded hole
def test_exit_task_with_no_bounds_is_unbounded():
    """Last task on its processor + no successors: nothing constrains the
    optional part.  Omitted by default; inf with include_unbounded."""
    tg = _two_proc_topology()
    g = SPG(n=2, edges=[], weights=np.array([4.0, 6.0]))
    s = Schedule(g, tg, proc=np.array([0, 1]), start=np.array([0.0, 0.0]),
                 finish=np.array([4.0, 6.0]), messages={})
    assert schedule_holes(s) == {}
    holes = schedule_holes(s, include_unbounded=True)
    assert holes == {0: float("inf"), 1: float("inf")}
    # the IC consumers treat inf correctly: the optional part always fits
    assert precision(4.0, holes[0], lam=3.0, ic=True) == 1.0
    assert precision(4.0, 0.0, lam=3.0, ic=False) == pytest.approx(1 / 3)


def test_exit_task_followed_on_processor_is_bounded():
    """An exit task is still bounded by the next task on its processor."""
    tg = _two_proc_topology()
    g = SPG(n=2, edges=[], weights=np.array([4.0, 6.0]))
    s = Schedule(g, tg, proc=np.array([0, 0]), start=np.array([0.0, 9.0]),
                 finish=np.array([4.0, 15.0]), messages={})
    holes = schedule_holes(s, include_unbounded=True)
    assert holes[0] == pytest.approx(5.0)          # 9 - 4, condition (a)
    assert holes[1] == float("inf")


# ------------------------------------------------------ Eq. 21 slack cap
def _cross_proc_schedule(rival_start):
    """Task 0 (p0) -> task 1 (p1) over link L; an unrelated message
    (2 -> 3, running p1 -> p0 over the same bidirectional link) sits on
    L starting at ``rival_start``.

    Task 0 finishes at 4; its message occupies L over [4, 6]; task 1
    starts at 20 (lots of successor-side slack); p0's next task (3)
    starts at 30 so condition (a) never binds.  The rival message
    occupies [rival_start, rival_start + 2].
    """
    tg = _two_proc_topology()
    g = SPG(n=4, edges=[(0, 1), (2, 3)],
            weights=np.array([4.0, 5.0, 3.0, 1.0]))
    m01 = MessagePlacement((0, 1), 0, 1, ("L",), [("L", 4.0, 6.0)])
    m23 = MessagePlacement((2, 3), 1, 0, ("L",),
                           [("L", rival_start, rival_start + 2.0)])
    s = Schedule(
        g, tg,
        proc=np.array([0, 1, 1, 0]),
        start=np.array([0.0, 20.0, 4.0, 30.0]),
        finish=np.array([4.0, 25.0, 7.0, 31.0]),
        messages={(0, 1): m01, (2, 3): m23})
    return g, s


def test_message_retiming_capped_by_queued_rival():
    """Eq. 21: LST'' slack is the gap to the rival queued behind the
    message on its link, not the (larger) successor-side slack."""
    g, s = _cross_proc_schedule(rival_start=9.0)
    holes = schedule_holes(s)
    # successor-side slack: start(1) - LFT = 20 - 6 = 14; link-side rival
    # gap: 9 - 6 = 3 < 14, so LST'' = LST + 3 = 7 and hole(0) = 7 - 4 = 3.
    assert holes[0] == pytest.approx(3.0)


def test_message_retiming_uses_successor_slack_without_rival():
    """With the rival far away, the successor's start is the binding
    constraint (slack = 14, capped at 14 by start(1))."""
    g, s = _cross_proc_schedule(rival_start=50.0)
    holes = schedule_holes(s)
    # slack = min(20 - 6, 50 - 6) = 14 -> LST'' = 4 + 14, hole = 18 - 4.
    assert holes[0] == pytest.approx(14.0)


def test_message_retiming_rival_queued_immediately():
    """A rival packed right behind the message leaves zero slack: the hole
    collapses to LST - AFT = 0 and is dropped."""
    g, s = _cross_proc_schedule(rival_start=6.0)
    holes = schedule_holes(s)
    assert 0 not in holes


def test_same_processor_successor_bound():
    """Condition (b): a same-processor successor bounds the hole by its
    start time directly."""
    tg = _two_proc_topology()
    g = SPG(n=2, edges=[(0, 1)], weights=np.array([4.0, 5.0]))
    s = Schedule(g, tg, proc=np.array([0, 0]), start=np.array([0.0, 10.0]),
                 finish=np.array([4.0, 15.0]), messages={})
    holes = schedule_holes(s)
    assert holes[0] == pytest.approx(6.0)          # 10 - 4
