"""Property-based tests (hypothesis) for the scheduler invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (SchedulingFailure, load_balance, paper_topology,
                        random_spg, schedule_hsv_cc, schedule_hvlb_cc, slr,
                        speedup)
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.scheduler import list_schedule

SETTINGS = settings(max_examples=25, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])

# shims called deliberately; their warning is pinned by
# tests/test_deprecation.py (keeps -W error::DeprecationWarning clean)
pytestmark = pytest.mark.filterwarnings(
    "ignore:schedule_h:DeprecationWarning")


def _graph(seed, n, ccr=1.0, constrained=True):
    rng = np.random.default_rng(seed)
    tg = paper_topology()
    g = random_spg(n, rng, ccr=ccr, tg=tg, outdeg_constraint=constrained)
    return g, tg


@given(seed=st.integers(0, 10_000), n=st.integers(8, 40))
@SETTINGS
def test_schedule_validity_invariants(seed, n):
    """Precedence, per-processor exclusivity, per-link exclusivity, task
    durations, message timing — for every random constrained graph."""
    g, tg = _graph(seed, n)
    s = schedule_hsv_cc(g, tg)
    s.validate()
    res = schedule_hvlb_cc(g, tg, variant="B", alpha_max=2.0,
                           alpha_step=0.25)
    res.best.validate()


@given(seed=st.integers(0, 10_000), n=st.integers(8, 40))
@SETTINGS
def test_hvlb_never_worse_than_hsv(seed, n):
    """The alpha sweep includes alpha=0 == HSV_CC, so min makespan over
    the sweep can never exceed HSV_CC's (with the HSV priority order)."""
    g, tg = _graph(seed, n)
    hsv = schedule_hsv_cc(g, tg)
    hvlb = schedule_hvlb_cc(g, tg, variant="A", alpha_max=2.0,
                            alpha_step=0.25)
    assert hvlb.best.makespan <= hsv.makespan + 1e-9


@given(seed=st.integers(0, 10_000), n=st.integers(8, 40))
@SETTINGS
def test_metrics_bounds(seed, n):
    g, tg = _graph(seed, n)
    s = schedule_hsv_cc(g, tg)
    assert slr(s) >= 1.0 - 1e-9              # makespan >= critical path
    assert speedup(s) > 0
    assert load_balance(s) >= 1.0 - 1e-9     # makespan >= avg proc load


@given(seed=st.integers(0, 10_000), n=st.integers(8, 40))
@SETTINGS
def test_depth2_indicator_never_fails(seed, n):
    """The 0%-SFR theorem: HPRV_B (indicator form) respects precedence on
    ANY random DAG (unconstrained out-degrees)."""
    g, tg = _graph(seed, n, constrained=False)
    r = rank_matrix(g, tg)
    q = priority_queue(hprv_b(g, tg, r), r.mean(1))
    pos = {t: i for i, t in enumerate(q)}
    assert all(pos[i] < pos[j] for (i, j) in g.edges)
    s = list_schedule(g, tg, q, r, alpha=0.0)   # must not raise
    s.validate()


@given(seed=st.integers(0, 10_000), n=st.integers(8, 30),
       ccr=st.sampled_from([0.1, 1.0, 10.0]))
@SETTINGS
def test_makespan_scales_with_ccr(seed, n, ccr):
    """Sanity: schedules stay valid across the CCR regimes of Exp. 3."""
    g, tg = _graph(seed, n, ccr=ccr)
    s = schedule_hsv_cc(g, tg)
    s.validate()
    assert s.makespan > 0


def test_brute_force_optimality_gap_small_graphs():
    """On tiny graphs, HVLB_CC's best schedule is close to the brute-force
    assignment optimum under the same timing model."""
    import itertools
    from repro.core.scheduler import _route_message

    rng = np.random.default_rng(3)
    tg = paper_topology()
    gaps = []
    for trial in range(5):
        g = random_spg(7, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
        hvlb = schedule_hvlb_cc(g, tg, variant="B", alpha_max=3.0,
                                alpha_step=0.05).best
        order = g.topo_order
        best = np.inf
        for assign in itertools.product(range(3), repeat=g.n):
            proc_free = np.zeros(3)
            link_free = {}
            aft = np.zeros(g.n)
            for j in order:
                p = assign[j]
                arrival = 0.0
                for i in sorted(g.pred[j], key=lambda i: (aft[i], i)):
                    if assign[i] == p:
                        arrival = max(arrival, aft[i])
                        continue
                    m = _route_message(g, tg, i, j, assign[i], p, aft[i],
                                       link_free)
                    for (l, st_, fi) in m.intervals:
                        link_free[l] = max(link_free.get(l, 0.0), fi)
                    arrival = max(arrival, m.lft)
                est = max(proc_free[p], arrival)
                aft[j] = est + g.comp(j, p, tg.rates)
                proc_free[p] = aft[j]
            best = min(best, aft.max())
        gaps.append(hvlb.makespan / best)
    assert np.mean(gaps) < 1.35, gaps     # near-optimal on average
