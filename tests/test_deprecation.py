"""The PR-2 shims: one real DeprecationWarning per process, output
bit-identical to the session API they wrap."""
import warnings

import numpy as np
import pytest

from repro.core import (HSV_CC, HVLB_CC_A, Scheduler, deprecation,
                        paper_spg, paper_topology, schedule_hsv_cc,
                        schedule_hvlb_cc, schedule_hvlb_cc_best)


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    deprecation.reset()
    yield
    deprecation.reset()


def test_schedule_hsv_cc_warns_once_and_matches_session():
    g, tg = paper_spg(), paper_topology()
    with pytest.warns(DeprecationWarning, match="schedule_hsv_cc"):
        s = schedule_hsv_cc(g, tg)
    # second call: shim stays usable, but silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s2 = schedule_hsv_cc(g, tg)
    ref = Scheduler(tg, policy=HSV_CC()).submit(g).schedule
    for other in (s2, ref):
        np.testing.assert_array_equal(s.proc, other.proc)
        np.testing.assert_array_equal(s.start, other.start)
        np.testing.assert_array_equal(s.finish, other.finish)


def test_schedule_hvlb_cc_warns_once_and_matches_session():
    g, tg = paper_spg(), paper_topology()
    with pytest.warns(DeprecationWarning, match="schedule_hvlb_cc"):
        res = schedule_hvlb_cc(g, tg, variant="A", alpha_max=1.0,
                               alpha_step=0.5, period=150.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res2 = schedule_hvlb_cc(g, tg, variant="A", alpha_max=1.0,
                                alpha_step=0.5, period=150.0)
    plan = Scheduler(tg).submit(g, HVLB_CC_A(alpha_max=1.0, alpha_step=0.5,
                                             period=150.0))
    for other in (res2, plan.sweep):
        np.testing.assert_array_equal(res.alphas, other.alphas)
        np.testing.assert_array_equal(res.makespans, other.makespans)
        assert res.best_alpha == other.best_alpha
        np.testing.assert_array_equal(res.best.finish, other.best.finish)


def test_schedule_hvlb_cc_best_warns_its_own_key():
    g, tg = paper_spg(), paper_topology()
    with pytest.warns(DeprecationWarning, match="schedule_hvlb_cc_best"):
        best = schedule_hvlb_cc_best(g, tg, alpha_max=1.0, alpha_step=0.5,
                                     period=150.0)
    # _best does not consume schedule_hvlb_cc's own once-flag
    with pytest.warns(DeprecationWarning, match="schedule_hvlb_cc is"):
        res = schedule_hvlb_cc(g, tg, variant="A", alpha_max=1.0,
                               alpha_step=0.5, period=150.0)
    np.testing.assert_array_equal(best.finish, res.best.finish)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        schedule_hvlb_cc_best(g, tg, alpha_max=1.0, alpha_step=0.5,
                              period=150.0)


def test_sweepresult_curve_warns_once():
    g, tg = paper_spg(), paper_topology()
    plan = Scheduler(tg).submit(g, HVLB_CC_A(alpha_max=1.0, alpha_step=0.5,
                                             period=150.0))
    with pytest.warns(DeprecationWarning, match="alphas"):
        pts = plan.sweep.curve
    assert pts == list(zip(plan.sweep.alphas.tolist(),
                           plan.sweep.makespans.tolist()))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan.sweep.curve