"""Quickstart: the paper's scheduler end-to-end through the session API.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (HSV_CC, HVLB_CC_B, HVLB_CC_IC, Scheduler, load_balance,
                        paper_spg, paper_topology, slr, speedup)

# 1. The paper's worked example: Fig. 3 graph on the Fig. 2 network,
#    submitted to a long-lived scheduler session (register once,
#    execute continuously — the DSMS loop).  backend= selects the
#    candidate-evaluation backend: "auto" (default) runs the scalar
#    loop on small topologies and the (P,)-batch vector backend from
#    P >= 8 — all backends are bit-identical, it is purely a speed knob.
g = paper_spg()
tg = paper_topology()
sched = Scheduler(tg, backend="auto")       # one session, shared compile

# 2. Baseline HSV_CC (Xie et al.) — tasks pile onto the fast processors.
hsv = sched.submit(g, HSV_CC()).schedule
print(f"HSV_CC   makespan={hsv.makespan:5.1f}  SLR={slr(hsv):.2f} "
      f"speedup={speedup(hsv):.2f}  LB={load_balance(hsv):.2f}")
for p in range(3):
    tasks = [f"n{i+1}" for i in hsv.tasks_on(p)]
    print(f"  p{p+1}: {tasks}")

# 3. HVLB_CC — load-balanced, contention-aware (Algorithm 1 alpha sweep).
plan = sched.submit(g, HVLB_CC_B(alpha_max=3.0, period=150.0))
best = plan.schedule
print(f"\nHVLB_CC(B) makespan={best.makespan:5.1f} "
      f"(alpha={plan.best_alpha:.2f}) SLR={slr(best):.2f} "
      f"speedup={speedup(best):.2f} LB={load_balance(best):.2f}")
for p in range(3):
    tasks = [f"n{i+1}" for i in best.tasks_on(p)]
    print(f"  p{p+1}: {tasks}")
# the sweep curve ships as plotting-ready arrays (Fig. 5)
print(f"sweep: {len(plan.sweep.alphas)} grid points, "
      f"makespan range [{plan.sweep.makespans.min():.0f}, "
      f"{plan.sweep.makespans.max():.0f}]")

# 4. Imprecise computation as a first-class policy (Section 4.4): the
#    plan carries its schedule holes and precision accessors directly.
ic = sched.submit(g, HVLB_CC_IC(alpha_max=3.0, period=150.0))
print("\nschedule holes:", {f"n{k+1}": round(v, 1)
                            for k, v in ic.holes.items()})

# 5. Online drift (Section 4.4): task n10's arrival rate drops 10%.
#    probe_update reports how much of the memoized decision trace
#    survives (rank recomputation only); update() then re-simulates just
#    that suffix, bit-identical to a fresh plan.  In this 10-task example
#    the drift reaches every ancestor rank so the whole trace re-runs —
#    the fleet-scale win is benchmarked in benchmarks/exp8_session_api.py.
b_policy = HVLB_CC_B(alpha_max=3.0, period=150.0)
surviving = sched.probe_update(task_rates={9: 0.9}, policy=b_policy)
upd = sched.update(task_rates={9: 0.9}, policy=b_policy)
print(f"\nafter drift: makespan={upd.makespan:.1f}; probe said "
      f"{surviving}/{g.n} decisions survive, update replayed "
      f"{upd.replay.decisions_replayed} and re-simulated "
      f"{upd.replay.decisions_simulated}")

# 6. Wide clusters: on P >= 8 processors "auto" resolves to the
#    vectorized backend; the plan records which numeric layer ran.
#    An explicit override is per-call: sched.submit(g, backend="scalar").
print(f"\nbackend on this 3-processor example: {upd.backend} "
      "(vector kicks in from P >= 8; backend='pallas' opts into the "
      "device kernel)")

# 7. Device offload (requires jax): backend="pallas" (opt-in; auto
#    never picks it) runs the engine's level-batched decision waves on
#    a Pallas kernel — one launch evaluates a whole wave of independent
#    tasks over all P candidates, commits winners to device-resident
#    link/processor state in-kernel, and pays one host round-trip per
#    wave (O(levels), not O(decisions)).  Batching is on by default;
#    batch= caps the wave size (batch=1 is the per-decision walk) and,
#    like backend=, keys the plan cache.  Interpret mode on CPU keeps
#    schedules decision-identical; a TPU run compiles f32 with the
#    documented near-tie policy (DESIGN.md §5).
try:
    import jax  # noqa: F401
    pallas_sched = Scheduler(tg, backend="pallas")      # batched default
    pp = pallas_sched.submit(g, HVLB_CC_B(alpha_max=3.0, period=150.0))
    print(f"pallas (batched, wave cap {pp.batch}): "
          f"makespan={pp.makespan:.1f} at alpha={pp.best_alpha:.2f} "
          "— decision-identical to the NumPy backends")
except (ImportError, ValueError):
    # no jax at all, or an importable-but-broken install rejected at
    # resolve time — either way the NumPy backends above still stand
    print("(jax not installed — backend='pallas' needs jax[cpu])")

print("\n(paper: HSV_CC=73, HVLB_CC=62 — see tests/test_paper_example.py)")
