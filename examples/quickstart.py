"""Quickstart: the paper's scheduler end-to-end in 40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (paper_spg, paper_topology, schedule_hsv_cc,
                        schedule_hvlb_cc, schedule_holes, slr, speedup,
                        load_balance)

# 1. The paper's worked example: Fig. 3 graph on the Fig. 2 network.
g = paper_spg()
tg = paper_topology()

# 2. Baseline HSV_CC (Xie et al.) — tasks pile onto the fast processors.
hsv = schedule_hsv_cc(g, tg)
print(f"HSV_CC   makespan={hsv.makespan:5.1f}  SLR={slr(hsv):.2f} "
      f"speedup={speedup(hsv):.2f}  LB={load_balance(hsv):.2f}")
for p in range(3):
    tasks = [f"n{i+1}" for i in hsv.tasks_on(p)]
    print(f"  p{p+1}: {tasks}")

# 3. HVLB_CC — load-balanced, contention-aware (Algorithm 1, alpha sweep).
res = schedule_hvlb_cc(g, tg, variant="B", alpha_max=3.0, period=150.0)
best = res.best
print(f"\nHVLB_CC(B) makespan={best.makespan:5.1f} (alpha={res.best_alpha:.2f}) "
      f"SLR={slr(best):.2f} speedup={speedup(best):.2f} "
      f"LB={load_balance(best):.2f}")
for p in range(3):
    tasks = [f"n{i+1}" for i in best.tasks_on(p)]
    print(f"  p{p+1}: {tasks}")

# 4. Schedule holes -> imprecise computation headroom (Section 4.4).
holes = schedule_holes(best)
print("\nschedule holes:", {f"n{k+1}": round(v, 1) for k, v in holes.items()})
print("\n(paper: HSV_CC=73, HVLB_CC=62 — see tests/test_paper_example.py)")
