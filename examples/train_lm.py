"""End-to-end training driver: real data pipeline, AdamW, checkpointing,
restart, on a reduced LM (CPU-friendly; same code path the dry-run lowers
for the full archs).

  PYTHONPATH=src python examples/train_lm.py --steps 30 --arch qwen2-0.5b
  PYTHONPATH=src python examples/train_lm.py --steps 30 --resume   # restart
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.configs import SHAPES, get_arch, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.params import init_params
from repro.optim import AdamWConfig
from repro.optim.adamw import init_opt_state
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--d-model", type=int, default=256,
                    help="width of the reduced config (~10M params; use "
                         "512+ for the ~100M regime)")
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced_config(get_arch(args.arch))
    cfg = dataclasses.replace(cfg, d_model=args.d_model,
                              n_layers=args.layers,
                              d_ff=args.d_model * 4 if cfg.d_ff else 0,
                              d_head=max(16, args.d_model // max(cfg.n_heads, 1)),
                              vocab=2048)
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    pipe = SyntheticTokenPipeline(cfg, shape, DataConfig(seed=0))
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3,
                                                       warmup_steps=20,
                                                       total_steps=args.steps)))

    start = 0
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    if args.resume:
        last = latest_step(args.ckpt)
        if last is not None:
            state = restore(args.ckpt, last, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = last
            print(f"resumed from step {last}")

    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")
    for step in range(start, args.steps):
        t0 = time.time()
        batch = pipe.device_batch(step)
        params, opt, info = step_fn(params, opt, batch)
        dt = time.time() - t0
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(info['loss']):.4f} "
                  f"gnorm={float(info['grad_norm']):.3f} {dt:5.2f}s")
        if (step + 1) % args.ckpt_every == 0:
            save(args.ckpt, step + 1, {"params": params, "opt": opt})
            print(f"  checkpoint @ {step + 1}")
    print("done.")


if __name__ == "__main__":
    main()
