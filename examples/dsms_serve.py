"""Automotive-DSMS serving example: registered continuous queries over a
decoding LM stream, statically scheduled with HVLB_CC, with an
imprecise-computation query that refines only when its schedule hole
allows (Section 4.4 of the paper, end to end).

  PYTHONPATH=src python examples/dsms_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.models.params import init_params
from repro.serve import DSMSEngine, Query

cfg = reduced_config(get_arch("qwen3-8b"))
params = init_params(cfg, jax.random.PRNGKey(0))
BATCH, MAX_SEQ = 4, 64

engine = DSMSEngine(cfg, params, batch_size=BATCH, max_seq=MAX_SEQ)

# Query 1: collision-warning analogue — threshold detector on max logit.
engine.register(Query(
    name="alert",
    mandatory=lambda logits: jnp.max(jax.nn.softmax(logits[:, -1]), -1),
))

# Query 2: navigation analogue — top-5 candidates, with an *optional*
# refinement (full sort) that only runs in schedule holes.
engine.register(Query(
    name="nav_topk",
    mandatory=lambda logits: jax.lax.top_k(logits[:, -1], 5),
    optional=lambda res: (res[0], res[1], jnp.sort(res[0])[..., ::-1]),
    optional_ratio=0.5,
))

# Query 3: logging analogue.
engine.register(Query(
    name="log_mean",
    mandatory=lambda logits: jnp.mean(logits[:, -1], -1),
))

# Registration is O(1): the schedule is computed once, lazily — three
# registrations cost one re-plan, not three.
assert engine.replans == 0
engine.ensure_plan()
print(f"engine: {len(engine.queries)} queries, {engine.replans} replan; "
      f"plan makespan={engine.plan.makespan * 1e3:.3f} ms on "
      f"{engine.topology.n_procs} slices")
print(f"holes: { {k: round(v*1e3, 3) for k, v in engine.holes.items()} } (ms)")

toks = np.zeros(BATCH, np.int64)
for t in range(8):
    res = engine.step(toks)
    toks = res.tokens
    prec = {k: ("precise" if v else "imprecise")
            for k, v in res.precise.items()}
    print(f"step {t}: tokens={res.tokens.tolist()} "
          f"alert={np.asarray(res.query_outputs['alert']).round(3).tolist()} "
          f"{prec}")
print("done.")


# ----------------------------------------------------------------------
# Scheduler-as-a-service quickstart (DESIGN.md §8).  Many logical
# clients share one async service: bursts of registrations coalesce into
# ONE submit_many fleet replan, bursts of drift updates into ONE batched
# suffix-replay update; tenants are sharded across worker lanes by
# consistent hashing.  (`python -m repro.service` serves the same ops
# over newline-delimited JSON on TCP.)
import asyncio                                             # noqa: E402

from repro.core import fully_switched_topology, random_spg  # noqa: E402
from repro.service import SchedulerService                  # noqa: E402


async def service_quickstart():
    tg = fully_switched_topology(4, rates=[1.0, 1.1, 0.9, 1.2],
                                 link_speeds=[1.0, 1.5, 0.9, 1.2])
    svc = SchedulerService(tg, workers=2)
    car = svc.client("carA")
    rng = np.random.default_rng(0)
    graphs = [random_spg(10, rng, tg=tg) for _ in range(3)]

    # a burst of registrations -> ONE fleet replan
    resps = await asyncio.gather(*[
        asyncio.ensure_future(car.register(g, name=f"q{k}"))
        for k, g in enumerate(graphs)])
    print(f"service: registered {len(resps)} query graphs with "
          f"{svc.stats.replans} replan; fleet makespan="
          f"{resps[0].result['makespan']:.3f}")

    # a burst of drift reports -> ONE batched suffix replay
    resps = await asyncio.gather(
        asyncio.ensure_future(car.update(task_rates={2: 1.5}, graph="q0")),
        asyncio.ensure_future(car.update(task_rates={4: 0.8}, graph="q1")))
    print(f"service: 2 drift updates folded into "
          f"{resps[0].result['replay']['coalesced']}-event replay "
          f"({svc.stats.replans} replans total)")

    # faults surface as structured responses, not exceptions
    resp = await car.mark_failed(proc=3)
    print(f"service: proc 3 down -> ok={resp.ok}, "
          f"makespan={resp.result['makespan']:.3f}, "
          f"faults={resp.result['faults']}")


asyncio.run(service_quickstart())
print("service quickstart done.")
