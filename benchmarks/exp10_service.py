"""Exp 10 (beyond-paper) — serving-layer throughput (DESIGN.md §8).

A seeded 8-tenant request trace is driven through
:class:`repro.service.SchedulerService` twice — coalescing on and off —
over a P=8 switched network.  Each tenant issues one burst of 4
registrations followed by 3 bursts of 3 drift updates (all tenants
concurrently; the per-tenant debounce folds each burst into one fleet
``submit_many`` / one batched suffix-replay ``update``).

Rows:

  * ``exp10.svc.t8.request_us`` — mean wall time per request with
    coalescing on; derived = sustained requests (schedules) per second.
  * ``exp10.svc.t8.p99_replan_us`` — p99 replan latency (us); derived =
    p99/mean replan-latency ratio (machine-independent tail metric,
    CI ceiling 25.0).
  * ``exp10.svc.t8.coalescing_replans`` — wall time of the coalesced
    run; derived = uncoalesced/coalesced scheduler-invocation ratio
    (CI floor 2.0 — the coalescing lever itself).

The run *asserts* the acceptance contract before emitting rows: the
final plan views of the coalesced and uncoalesced runs are identical,
and each tenant's final fleet schedule is bit-identical to a direct
fresh single-session ``Scheduler.submit_many`` on the same final state
(graphs after drift, faults, pinned period).

``engine`` is accepted for driver compatibility but ignored: the
service always runs compiled sessions (the serving layer exists to
exploit their incremental replay).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import HVLB_CC_B, Scheduler, fully_switched_topology, random_spg
from repro.service import SchedulerService

from .common import row, timed

_RATES = [1.0, 1.2, 0.9, 1.1, 1.3, 0.95, 1.05, 0.8]
_SPEEDS = [1.0, 2.0, 1.5, 1.0, 3.0, 2.5, 1.0, 2.0]
_TENANTS = 8
_GRAPHS = 4
_BURSTS = 3          # update bursts per tenant
_EVENTS = 3          # drift events per burst


def _make_trace(full: bool):
    tg = fully_switched_topology(8, _RATES, _SPEEDS)
    n = 28 if full else 14
    tenants = []
    for t in range(_TENANTS):
        rng = np.random.default_rng(10_000 + t)
        graphs = [random_spg(n, rng, ccr=1.0, tg=tg,
                             outdeg_constraint=True)
                  for _ in range(_GRAPHS)]
        for k, g in enumerate(graphs):
            g.name = f"t{t}g{k}"
        bursts = [[(f"t{t}g{int(rng.integers(_GRAPHS))}",
                    int(rng.integers(n)),
                    float(rng.uniform(0.7, 1.4)))
                   for _ in range(_EVENTS)]
                  for _ in range(_BURSTS)]
        tenants.append((f"tenant{t}", graphs, bursts))
    return tg, tenants


async def _drive(svc: SchedulerService, tenants):
    clients = {name: svc.client(name) for name, _, _ in tenants}
    # concurrent registration bursts, one per tenant
    futs = [asyncio.ensure_future(clients[name].register(g, name=g.name))
            for name, graphs, _ in tenants for g in graphs]
    for resp in await asyncio.gather(*futs):
        assert resp.ok, resp.error
    # drift bursts (all tenants concurrently, burst by burst)
    for b in range(_BURSTS):
        futs = [asyncio.ensure_future(
                    clients[name].update(task_rates={task: f},
                                         graph=gname))
                for name, _, bursts in tenants
                for gname, task, f in bursts[b]]
        for resp in await asyncio.gather(*futs):
            assert resp.ok, resp.error
    # final plan views
    finals = {}
    for name, graphs, _ in tenants:
        for g in graphs:
            resp = await clients[name].plan(graph=g.name)
            assert resp.ok, resp.error
            finals[(name, g.name)] = resp.result
    return finals


def run(full: bool = False, engine: str = "compiled",
        backend: Optional[str] = None) -> List[str]:
    del engine                      # service sessions are always compiled
    tg, tenants = _make_trace(full)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)

    def _run(coalesce: bool):
        svc = SchedulerService(tg, policy, workers=4,
                               coalesce=coalesce, backend=backend)
        finals = asyncio.run(_drive(svc, tenants))
        svc.close()
        return svc, finals

    (svc_on, fin_on), us_on = timed(_run, True)
    (svc_off, fin_off), _ = timed(_run, False)

    # responses must not depend on coalescing at all
    assert fin_on == fin_off, "coalesced/uncoalesced responses diverge"
    # ... and must match a direct single-session Scheduler on the final
    # state (graphs after drift, recorded faults, pinned fleet period)
    for name, graphs, _ in tenants:
        t = svc_on._tenants[name]
        view = fin_on[(name, graphs[0].name)]
        fresh = Scheduler(
            t.topology,
            policy=dataclasses.replace(policy, period=view["period"]),
            faults=t.fault_records)
        fleet = fresh.submit_many(list(t.graphs.values()))
        assert float(fleet.makespan) == view["makespan"]
        assert [int(x) for x in fleet.subschedule(0).proc] == view["proc"]

    n_req = svc_on.stats.requests
    mean_us = svc_on.stats.mean_replan_latency_s() * 1e6
    p99_us = svc_on.stats.p99_replan_latency_s() * 1e6
    ratio = svc_off.stats.replans / svc_on.stats.replans
    return [
        row("exp10.svc.t8.request_us", us_on / n_req,
            n_req / (us_on / 1e6)),
        row("exp10.svc.t8.p99_replan_us", p99_us,
            p99_us / mean_us if mean_us else 0.0),
        row("exp10.svc.t8.coalescing_replans", us_on, ratio),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
