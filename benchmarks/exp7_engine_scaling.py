"""Exp 7 (beyond-paper) — compiled-engine scheduler throughput scaling.

Measures scheduler latency for n in {50, 100, 200, 500} tasks on P in
{3, 8, 16} processors:

  * ``compile_us``      — one-time CompiledInstance preprocessing cost,
  * ``schedule_us``     — a single list-schedule pass on the *scalar*
                          candidate-evaluation backend (the online
                          re-plan unit cost; ``derived`` =
                          schedules/second),
  * ``vec_schedule_us`` — the same pass on the (P,)-batch *vector*
                          backend (P >= 8 only; ``derived`` = the
                          same-run scalar/vector speedup — the
                          machine-independent number the regression
                          gate watches),
  * ``cold_submit_us``  — the *first* vector pass on a freshly
                          compiled instance (P >= 8), which pays the
                          shared per-src route-tensor layout builds
                          (``derived`` = cold/warm ratio; the shared
                          layout precompute keeps it ~1.2x at n=500
                          where the per-(edge, src) builds used to
                          cost ~2x),
  * ``pallas_schedule_us`` — the same pass on the JAX/Pallas device
                          backend in interpreter mode with ``batch=1``
                          (the PR-4 per-decision dispatch baseline;
                          n=50 rows only, skipped when jax is not
                          installed; ``derived`` = scalar/pallas ratio
                          — well below 1 under the interpreter),
  * ``pallas_batched_schedule_us`` — the level-batched pallas path
                          (one kernel launch + one host round-trip per
                          wave; ``derived`` = per-decision/batched
                          speedup — what the O(levels) launch
                          amortization buys),
  * ``scan_schedule_us`` — the whole-schedule ``lax.scan`` path (the
                          shipping default: ONE dispatch per plan;
                          ``derived`` = per-wave/scan speedup — what
                          folding the wave loop into the device buys),
  * ``pallas_roundtrips`` — host<->device transitions per scan-path
                          schedule (state upload + launch + final
                          fetch); ``derived`` = the same count, gated
                          in CI at a constant 3 (O(1), not O(levels)),
  * ``scan_vs_wave``     — (P=8, n=500 only) warm per-wave/scan
                          speedup at scale; ``derived`` is floored in
                          CI at 1.5x,
  * ``sweep_us``        — a full HVLB_CC alpha sweep (alpha_max=5,
                          step=0.05) with decision-trace interval
                          skipping (``derived`` = distinct makespan
                          plateaus across the 101 steps).

The reference implementation is timed alongside at the two smaller sizes
(``ref_schedule_us``) so the per-call engine speedup is visible in the CSV.
Scalar and vector passes are asserted bit-identical here, on the actual
benchmark workload.
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from repro.core import (CompiledInstance, HVLB_CC_B, Scheduler,
                        fully_switched_topology, paper_topology, random_spg)
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.scheduler import list_schedule

from .common import row, timed

SIZES = (50, 100, 200, 500)
PROCS = (3, 8, 16)


def _has_jax() -> bool:
    import importlib.util
    return importlib.util.find_spec("jax") is not None


def _topology(P: int):
    if P == 3:
        return paper_topology()
    rng = np.random.default_rng(77)
    return fully_switched_topology(
        P, rates=rng.uniform(0.6, 1.2, size=P),
        link_speeds=rng.uniform(0.5, 3.0, size=P))


def _min_of(repeats: int, *fns) -> List[float]:
    """Min-over-repeats latency in us for each callable, with the
    repeats *interleaved* so drifting machine load hits every candidate
    equally — the robust estimator on shared-CI runners (the first
    repeat also warms instance-level caches)."""
    best = [float("inf")] * len(fns)
    for _ in range(repeats):
        for k, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            best[k] = min(best[k], (time.perf_counter() - t0) * 1e6)
    return best


def run(full: bool = False, engine: str = "compiled",
        backend: Optional[str] = None) -> List[str]:
    compiled = engine == "compiled"
    rows: List[str] = []
    repeats = 7 if full else 5
    for P in PROCS:
        tg = _topology(P)
        for n in SIZES:
            if not compiled and n > 100:
                continue        # reference at n >= 200 is minutes per sweep
            rng = np.random.default_rng(7000 + n + P)
            # degree caps relaxed beyond the paper's (2, 3): the tight
            # family is unreliable to sample in the hundreds of tasks
            g = random_spg(n, rng, ccr=1.0, tg=tg, max_in=3, max_out=6)
            r = rank_matrix(g, tg)
            q = priority_queue(hprv_b(g, tg, r), r.mean(1))
            inst, compile_us = timed(CompiledInstance, g, tg, rank=r)

            res = {}
            if compiled and P >= 8:
                (sched_us, vec_us) = _min_of(
                    repeats,
                    lambda: res.__setitem__("s", inst.schedule(
                        q, alpha=1.0, backend="scalar")),
                    lambda: res.__setitem__("v", inst.schedule(
                        q, alpha=1.0, backend="vector")))
            elif compiled:
                (sched_us,) = _min_of(repeats, lambda: res.__setitem__(
                    "s", inst.schedule(q, alpha=1.0, backend="scalar")))
                vec_us = None
            else:
                (sched_us,) = _min_of(repeats, lambda: res.__setitem__(
                    "s", list_schedule(g, tg, q, r, alpha=1.0)))
                vec_us = None
            s = res["s"]
            rows.append(row(f"exp7.P{P}.n{n}.compile_us", compile_us,
                            float(compile_us)))
            rows.append(row(f"exp7.P{P}.n{n}.schedule_us", sched_us,
                            1e6 / sched_us))         # schedules/second
            if vec_us is not None:
                # the (P,)-batch backend, held bit-identical on the spot
                assert np.array_equal(res["v"].finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.vec_schedule_us", vec_us,
                                sched_us / vec_us))  # scalar/vector speedup
                # cold submit: first vector pass on a fresh instance pays
                # the shared per-src layout builds, nothing per-edge
                cold_us = float("inf")
                for _ in range(3):
                    inst2 = CompiledInstance(g, tg, rank=r)
                    t0 = time.perf_counter()
                    s2 = inst2.schedule(q, alpha=1.0, backend="vector")
                    cold_us = min(cold_us,
                                  (time.perf_counter() - t0) * 1e6)
                assert np.array_equal(s2.finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.cold_submit_us", cold_us,
                                cold_us / vec_us))   # cold/warm ratio
            if compiled and n == 50 and _has_jax():
                # device backend (interpret mode off-TPU), decision-
                # identical to scalar on the spot.  batch=1 is the PR-4
                # per-decision dispatch kept as the honest baseline and
                # the per-wave path is the PR-9 level-batched one; both
                # need the whole-schedule scan disabled (the knob is
                # read per call, so toggling the env var around the
                # timed passes is enough)
                import os
                os.environ["REPRO_PALLAS_SCAN"] = "0"
                try:
                    (pallas_us,) = _min_of(2, lambda: res.__setitem__(
                        "p", inst.schedule(q, alpha=1.0, backend="pallas",
                                           batch=1)))
                    assert np.array_equal(res["p"].proc, s.proc)
                    assert np.allclose(res["p"].finish, s.finish)
                    rows.append(row(f"exp7.P{P}.n{n}.pallas_schedule_us",
                                    pallas_us, sched_us / pallas_us))
                    (pallas_b_us,) = _min_of(2, lambda: res.__setitem__(
                        "pb", inst.schedule(q, alpha=1.0,
                                            backend="pallas")))
                    assert np.array_equal(res["pb"].proc, s.proc)
                    assert np.allclose(res["pb"].finish, s.finish)
                    rows.append(row(
                        f"exp7.P{P}.n{n}.pallas_batched_schedule_us",
                        pallas_b_us, pallas_us / pallas_b_us))
                finally:
                    os.environ.pop("REPRO_PALLAS_SCAN", None)
                # whole-schedule scan path (the shipping default): the
                # entire plan is ONE dispatch; derived = per-wave/scan
                # speedup, i.e. what folding the wave loop into the
                # device buys on this machine
                be = inst.backend_instance("pallas")
                c0 = be.n_launches + be.n_state_uploads + be.n_roundtrips
                (scan_us,) = _min_of(2, lambda: res.__setitem__(
                    "sc", inst.schedule(q, alpha=1.0, backend="pallas")))
                assert np.array_equal(res["sc"].proc, s.proc)
                assert np.allclose(res["sc"].finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.scan_schedule_us",
                                scan_us, pallas_b_us / scan_us))
                # host<->device transitions per schedule (state upload
                # + launch + final fetch): a CONSTANT — 3, not
                # O(levels) — gated in CI at <= 3 for every P
                transitions = (be.n_launches + be.n_state_uploads
                               + be.n_roundtrips - c0) // 2  # 2 repeats
                rows.append(row(f"exp7.P{P}.n{n}.pallas_roundtrips",
                                float(transitions), float(transitions)))
            if compiled and n == 500 and P == 8 and _has_jax():
                # scan-vs-per-wave at scale, the machine-independent
                # floor CI watches (derived = warm per-wave/scan
                # speedup; one untimed pass each pays compilation)
                import os
                os.environ["REPRO_PALLAS_SCAN"] = "0"
                try:
                    (wave_us,) = _min_of(3, lambda: res.__setitem__(
                        "w5", inst.schedule(q, alpha=1.0,
                                            backend="pallas")))
                finally:
                    os.environ.pop("REPRO_PALLAS_SCAN", None)
                assert np.array_equal(res["w5"].proc, s.proc)
                (scan5_us,) = _min_of(3, lambda: res.__setitem__(
                    "sc5", inst.schedule(q, alpha=1.0, backend="pallas")))
                assert np.array_equal(res["sc5"].proc, s.proc)
                assert np.allclose(res["sc5"].finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.scan_vs_wave", scan5_us,
                                wave_us / scan5_us))
            if compiled and n <= 100:
                t0 = time.perf_counter()
                ref = list_schedule(g, tg, q, r, alpha=1.0)
                ref_us = (time.perf_counter() - t0) * 1e6
                assert np.array_equal(ref.finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.ref_schedule_us", ref_us,
                                ref_us / sched_us))  # engine speedup
            if backend == "pallas" and n > 50:
                continue    # interpret-mode sweeps: minutes per point
            if n <= 200 and (P <= 8 or n <= 100):
                plan, sweep_us = timed(
                    Scheduler(tg, engine=engine, backend=backend).submit, g,
                    HVLB_CC_B(alpha_max=5.0, alpha_step=0.05))
                sim_pts = len(set(plan.sweep.makespans.tolist()))
                rows.append(row(f"exp7.P{P}.n{n}.sweep_us", sweep_us,
                                float(sim_pts)))
    return rows
