"""Exp 7 (beyond-paper) — compiled-engine scheduler throughput scaling.

Measures scheduler latency for n in {50, 100, 200, 500} tasks on P in
{3, 8} processors:

  * ``compile_us``   — one-time CompiledInstance preprocessing cost,
  * ``schedule_us``  — a single list-schedule pass (the online re-plan
                       unit cost; ``derived`` = schedules/second),
  * ``sweep_us``     — a full HVLB_CC alpha sweep (alpha_max=5, step=0.05)
                       with decision-trace interval skipping (``derived`` =
                       distinct makespan plateaus across the 101 steps).

The reference implementation is timed alongside at the two smaller sizes
(``ref_schedule_us``) so the per-call engine speedup is visible in the CSV.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (CompiledInstance, HVLB_CC_B, Scheduler,
                        fully_switched_topology, paper_topology, random_spg)
from repro.core.ranks import hprv_b, priority_queue, rank_matrix
from repro.core.scheduler import list_schedule

from .common import row, timed

SIZES = (50, 100, 200, 500)


def _topology(P: int):
    if P == 3:
        return paper_topology()
    rng = np.random.default_rng(77)
    return fully_switched_topology(
        P, rates=rng.uniform(0.6, 1.2, size=P),
        link_speeds=rng.uniform(0.5, 3.0, size=P))


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    compiled = engine == "compiled"
    rows: List[str] = []
    repeats = 5 if full else 3
    for P in (3, 8):
        tg = _topology(P)
        for n in SIZES:
            if not compiled and n > 100:
                continue        # reference at n >= 200 is minutes per sweep
            rng = np.random.default_rng(7000 + n + P)
            # degree caps relaxed beyond the paper's (2, 3): the tight
            # family is unreliable to sample in the hundreds of tasks
            g = random_spg(n, rng, ccr=1.0, tg=tg, max_in=3, max_out=6)
            r = rank_matrix(g, tg)
            q = priority_queue(hprv_b(g, tg, r), r.mean(1))
            inst, compile_us = timed(CompiledInstance, g, tg, rank=r)

            # min over repeats: the robust latency estimator (shared-CI
            # runners make a mean-of-3 too noisy for the regression gate)
            sched_us = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                if compiled:
                    s = inst.schedule(q, alpha=1.0)
                else:
                    s = list_schedule(g, tg, q, r, alpha=1.0)
                sched_us = min(sched_us,
                               (time.perf_counter() - t0) * 1e6)
            rows.append(row(f"exp7.P{P}.n{n}.compile_us", compile_us,
                            float(compile_us)))
            rows.append(row(f"exp7.P{P}.n{n}.schedule_us", sched_us,
                            1e6 / sched_us))         # schedules/second
            if compiled and n <= 100:
                t0 = time.perf_counter()
                ref = list_schedule(g, tg, q, r, alpha=1.0)
                ref_us = (time.perf_counter() - t0) * 1e6
                assert np.array_equal(ref.finish, s.finish)
                rows.append(row(f"exp7.P{P}.n{n}.ref_schedule_us", ref_us,
                                ref_us / sched_us))  # engine speedup
            if n <= 200:
                plan, sweep_us = timed(
                    Scheduler(tg, engine=engine).submit, g,
                    HVLB_CC_B(alpha_max=5.0, alpha_step=0.05))
                sim_pts = len({m for _, m in plan.sweep.curve})
                rows.append(row(f"exp7.P{P}.n{n}.sweep_us", sweep_us,
                                float(sim_pts)))
    return rows
