"""Exp 3 (Fig. 9) — SLR vs CCR in {0.1, 0.5, 1, 5, 10}, n = 20 tasks,
rates (0.83, 1.0, 0.67)."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, Scheduler,
                        paper_topology, random_spg, slr)

from .common import row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    n_graphs = 100 if full else 20
    alpha_max = 20.0 if full else 5.0
    tg = paper_topology(rates=(0.83, 1.0, 0.67))
    for ccr in (0.1, 0.5, 1.0, 5.0, 10.0):
        rng = np.random.default_rng(int(3000 + ccr * 10))
        slrs = {k: [] for k in ("hsv", "hvlbA", "hvlbB")}
        us_tot = {k: 0.0 for k in slrs}
        for _ in range(n_graphs):
            g = random_spg(20, rng, ccr=ccr, tg=tg, outdeg_constraint=True)
            # fresh session per timed row: per-call semantics, rows stay
            # comparable with earlier BENCH snapshots
            plan, us = timed(lambda: Scheduler(
                tg, engine=engine).submit(g, HSV_CC()))
            slrs["hsv"].append(slr(plan.schedule)); us_tot["hsv"] += us
            for policy, key in (
                    (HVLB_CC_A(alpha_max=alpha_max, alpha_step=0.05),
                     "hvlbA"),
                    (HVLB_CC_B(alpha_max=alpha_max, alpha_step=0.05),
                     "hvlbB")):
                plan, us = timed(lambda p=policy: Scheduler(
                    tg, engine=engine).submit(g, p))
                slrs[key].append(slr(plan.schedule)); us_tot[key] += us
        for key, vals in slrs.items():
            us = us_tot[key] / n_graphs
            rows.append(row(f"exp3.ccr{ccr:g}.{key}.slr_mean", us,
                            float(np.mean(vals))))
            rows.append(row(f"exp3.ccr{ccr:g}.{key}.slr_worst", us,
                            float(np.max(vals))))
            rows.append(row(f"exp3.ccr{ccr:g}.{key}.slr_best", us,
                            float(np.min(vals))))
    return rows
