"""Exp 4 (Fig. 10) — scheduling failure rate on unconstrained random DAGs.

Paper: HSV_CC 78%, HVLB_CC(depth) 29%, HVLB_CC(depth^2) 0%.
We report four prioritizers: HSV_CC, the literal Eq.-9 form at depth^1 and
depth^2, and the indicator form at depth^2 (the paper's Table-2 semantics,
provably 0% — see ranks.hprv_b).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import paper_topology, random_spg, sfr
from repro.core.ranks import hprv_a, hprv_b, priority_queue, rank_matrix

from .common import row, timed


def run(full: bool = False) -> List[str]:
    rows: List[str] = []
    n_graphs = 1000 if full else 200
    tg = paper_topology()
    rng = np.random.default_rng(4000)
    fails = {"hsv": 0, "depth1_literal": 0, "depth2_literal": 0,
             "depth2_indicator": 0}
    us_tot = 0.0

    def variants(g, r):
        return {
            "hsv": hprv_a(g, tg, r),
            "depth1_literal": hprv_b(g, tg, r, depth_power=1,
                                     outd_mode="literal"),
            "depth2_literal": hprv_b(g, tg, r, depth_power=2,
                                     outd_mode="literal"),
            "depth2_indicator": hprv_b(g, tg, r, depth_power=2),
        }

    for _ in range(n_graphs):
        n = int(rng.integers(10, 51))
        g = random_spg(n, rng, ccr=1.0, tg=tg, outdeg_constraint=False)
        (r, _), us = timed(lambda: (rank_matrix(g, tg), None))
        us_tot += us
        h = r.mean(1)
        for name, prv in variants(g, r).items():
            q = priority_queue(prv, h)
            pos = {t: i for i, t in enumerate(q)}
            if any(pos[i] > pos[j] for (i, j) in g.edges):
                fails[name] += 1
    for name, f in fails.items():
        rows.append(row(f"exp4.{name}.sfr_pct", us_tot / n_graphs,
                        sfr(f, n_graphs)))
    return rows
