"""Exp 6 (beyond-paper) — the paper's scheduler as a TPU pipeline/pod
placement planner.

Workloads per architecture:
  pipe   — 8-microbatch pipeline DAG over 8 mesh slices (2 pods, shared
           DCN bus = the paper's gateway/contention model),
  pipe+straggler — same with slice 3 degraded to 0.6x (mixed-generation /
           thermally-throttled pod), the static re-plan answer,
  dsms   — multi-query serving graph (3 applications tapping a shared
           backbone): HSV_CC cannot even order it (Section 3.2), HVLB_CC
           (B) schedules it.
"""
from __future__ import annotations

from typing import List

from repro.configs import ARCHS, SHAPES
from repro.core.scheduler import SchedulingFailure
from repro.planner import (pipeline_graph, plan_placement,
                           serving_query_graph, tpu_slice_topology)

from .common import row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    archs = sorted(ARCHS) if full else ["qwen3-8b", "zamba2-2.7b",
                                        "dbrx-132b", "falcon-mamba-7b"]
    tg = tpu_slice_topology(n_slices=8, chips_per_slice=32, pods=2)
    tg_bad = tpu_slice_topology(n_slices=8, chips_per_slice=32, pods=2,
                                degraded={3: 0.6})
    for arch in archs:
        cfg = ARCHS[arch]
        g = pipeline_graph(cfg, SHAPES["train_4k"], n_microbatches=8)
        for name, topo in (("pipe", tg), ("pipe_straggler", tg_bad)):
            for alg in ("hsv", "hvlb_b"):
                try:
                    plan, us = timed(plan_placement, g, topo, alg, engine=engine)
                    rows.append(row(f"exp6.{arch}.{name}.{alg}.makespan_ms",
                                    us, plan.makespan_s * 1e3))
                    rows.append(row(f"exp6.{arch}.{name}.{alg}.lb",
                                    us, plan.load_balance))
                except SchedulingFailure:
                    rows.append(row(f"exp6.{arch}.{name}.{alg}.makespan_ms",
                                    0.0, "schedule_failure"))
        q = serving_query_graph(cfg, SHAPES["decode_32k"], n_queries=3)
        for alg in ("hsv", "hvlb_b"):
            try:
                plan, us = timed(plan_placement, q, tg, alg, engine=engine)
                rows.append(row(f"exp6.{arch}.dsms.{alg}.makespan_ms",
                                us, plan.makespan_s * 1e3))
            except SchedulingFailure:
                rows.append(row(f"exp6.{arch}.dsms.{alg}.makespan_ms",
                                0.0, "schedule_failure"))
    return rows
