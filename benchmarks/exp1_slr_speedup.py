"""Exp 1 (Fig. 7) — SLR and speedup vs number of tasks.

Random out-degree-constrained SPGs (the family HSV_CC can schedule), three
processor execution-rate patterns, CCR = 1.  Reports mean/worst SLR and
mean/best speedup for HSV_CC vs HVLB_CC (A)/(B).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, Scheduler,
                        paper_topology, random_spg, slr, speedup)

from .common import RATE_PATTERNS, row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    n_graphs = 100 if full else 20
    alpha_max = 20.0 if full else 5.0
    sizes = [10, 20, 30, 40, 50]
    for rates in RATE_PATTERNS[:3]:
        tg = paper_topology(rates=rates)
        tag = "r" + "-".join(f"{x:g}" for x in rates)
        for n in sizes:
            rng = np.random.default_rng(1000 + n)
            stats = {k: ([], []) for k in ("hsv", "hvlbA", "hvlbB")}
            us_tot = {k: 0.0 for k in stats}
            for _ in range(n_graphs):
                g = random_spg(n, rng, ccr=1.0, tg=tg,
                               outdeg_constraint=True)
                # fresh session per timed row so every row keeps the
                # pre-session per-call semantics (setup cost included) and
                # stays comparable with earlier BENCH snapshots
                plan, us = timed(lambda: Scheduler(
                    tg, engine=engine).submit(g, HSV_CC()))
                stats["hsv"][0].append(slr(plan.schedule))
                stats["hsv"][1].append(speedup(plan.schedule))
                us_tot["hsv"] += us
                for policy, key in (
                        (HVLB_CC_A(alpha_max=alpha_max, alpha_step=0.05),
                         "hvlbA"),
                        (HVLB_CC_B(alpha_max=alpha_max, alpha_step=0.05),
                         "hvlbB")):
                    plan, us = timed(lambda p=policy: Scheduler(
                        tg, engine=engine).submit(g, p))
                    stats[key][0].append(slr(plan.schedule))
                    stats[key][1].append(speedup(plan.schedule))
                    us_tot[key] += us
            for key, (slrs, sps) in stats.items():
                us = us_tot[key] / n_graphs
                rows.append(row(f"exp1.{tag}.n{n}.{key}.slr_mean", us,
                                float(np.mean(slrs))))
                rows.append(row(f"exp1.{tag}.n{n}.{key}.slr_worst", us,
                                float(np.max(slrs))))
                rows.append(row(f"exp1.{tag}.n{n}.{key}.speedup_mean", us,
                                float(np.mean(sps))))
                rows.append(row(f"exp1.{tag}.n{n}.{key}.speedup_best", us,
                                float(np.max(sps))))
    return rows
