"""Shared helpers for the paper-experiment benchmarks.

Every benchmark emits ``name,us_per_call,derived`` CSV rows; ``us_per_call``
is the wall time of one scheduler invocation (the paper's algorithms are
compile-time/offline, so latency of the scheduler itself is the system
cost), ``derived`` is the experiment's metric (SLR / speedup / LB / SFR /
precision / makespan).
"""
from __future__ import annotations

import time
from typing import Callable, List, Sequence, Tuple

import numpy as np

from repro.core import Topology, paper_topology

# The six execution-rate patterns of Section 5.2 (three quoted in the paper).
RATE_PATTERNS: List[Tuple[float, float, float]] = [
    (1.0, 0.67, 0.83),
    (0.83, 0.67, 1.0),
    (0.67, 0.83, 1.0),
    (1.0, 0.83, 0.67),
    (0.83, 1.0, 0.67),
    (0.67, 1.0, 0.83),
]


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived) -> str:
    if isinstance(derived, float):
        derived = f"{derived:.4f}"
    return f"{name},{us:.1f},{derived}"


def emit(rows: Sequence[str]) -> None:
    for r in rows:
        print(r)
