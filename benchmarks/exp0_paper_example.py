"""Exp 0 — the paper's worked example (Figs. 4-6, Tables 1-3).

Reproduces: HSV_CC makespan 73, HVLB_CC (A)/(B) makespan 62, and the
Fig. 5 alpha sweep plateau boundaries.

One Scheduler session is shared across the three policy rows, so the
HSV row's time includes the graph compile (rank/LDET/instance) while
the HVLB rows reuse it — the session API's intended cost profile.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, Scheduler, paper_spg,
                        paper_topology)

from .common import row, timed


def run(full: bool = False, engine: str = "compiled",
        backend: Optional[str] = None) -> List[str]:
    rows: List[str] = []
    g, tg = paper_spg(), paper_topology()
    sched = Scheduler(tg, engine=engine,     # one session, shared instance
                  backend=backend)
    plan, us = timed(sched.submit, g, HSV_CC())
    rows.append(row("exp0.hsv_cc.makespan", us, plan.makespan))
    for variant, policy in (("A", HVLB_CC_A(alpha_max=3.0, period=150.0)),
                            ("B", HVLB_CC_B(alpha_max=3.0, period=150.0))):
        plan, us = timed(sched.submit, g, policy)
        rows.append(row(f"exp0.hvlb_cc_{variant}.makespan", us,
                        plan.makespan))
        rows.append(row(f"exp0.hvlb_cc_{variant}.best_alpha", us,
                        plan.best_alpha))
    return rows
