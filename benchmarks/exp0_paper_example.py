"""Exp 0 — the paper's worked example (Figs. 4-6, Tables 1-3).

Reproduces: HSV_CC makespan 73, HVLB_CC (A)/(B) makespan 62, and the
Fig. 5 alpha sweep plateau boundaries.
"""
from __future__ import annotations

from typing import List

from repro.core import paper_spg, paper_topology, schedule_hsv_cc, \
    schedule_hvlb_cc

from .common import row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    g, tg = paper_spg(), paper_topology()
    s, us = timed(schedule_hsv_cc, g, tg, engine=engine)
    rows.append(row("exp0.hsv_cc.makespan", us, s.makespan))
    for variant in ("A", "B"):
        res, us = timed(schedule_hvlb_cc, g, tg, variant=variant,
                        alpha_max=3.0, period=150.0, engine=engine)
        rows.append(row(f"exp0.hvlb_cc_{variant}.makespan", us,
                        res.best.makespan))
        rows.append(row(f"exp0.hvlb_cc_{variant}.best_alpha", us,
                        res.best_alpha))
    return rows
