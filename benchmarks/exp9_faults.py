"""Exp 9 (beyond-paper) — fault-recovery economics (DESIGN.md §6).

For each fault class the session absorbs the fault mid-run via the
fault-invalidation replay path (``mark_failed``/``degrade``) and the row
records the *recovery latency* (``us_per_call`` — one replan) with the
*prefix-survival fraction* as the derived metric: the share of the
decision trace provably untouched by the failed resource that was
re-committed instead of re-simulated (``1 - invalidated/n``).

The gated scenario (CI: derived >= 0.5) is a P=8 switched network with
one cold-standby ECU (rate 0.3 — spare capacity the balancer never
elects) losing exactly that ECU.  Every alpha trace provably avoids it,
so *exact* fault invalidation keeps the entire prefix (survival 1.0);
the gate catches any regression where a fault replan needlessly
re-simulates decisions the dead resource never touched.  Losing a *hot*
processor is reported alongside (``proc_down_worst``, ungated): its
first placement — in the heaviest-balancing alpha trace of the sweep —
is early, so almost the whole trace legitimately re-simulates.
Survival is a property of which resource dies, not a constant the
scheduler could promise.

``link_down`` picks the dead link per graph as the first (sorted) link
whose loss keeps the committed prefix feasible; partitions of an already
split prefix raise :class:`InfeasibleScheduleError` by design and are
skipped here (the chaos harness covers them).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import (HVLB_CC_B, InfeasibleScheduleError, Scheduler,
                        fully_switched_topology, random_spg)

from .common import row, timed

# one clearly slowest processor (index 7) — the gated fault target
_RATES = [1.0, 1.2, 0.9, 1.1, 1.3, 0.95, 1.05, 0.3]
_SPEEDS = [1.0, 2.0, 1.5, 1.0, 3.0, 2.5, 1.0, 2.0]


def _survival(plan, n: int) -> float:
    return 1.0 - plan.replay.invalidated_by_fault / n


def run(full: bool = False, engine: str = "compiled",
        backend: Optional[str] = None) -> List[str]:
    rows: List[str] = []
    P, n = 8, (240 if full else 120)
    reps = 5 if full else 3
    tg = fully_switched_topology(P, _RATES, _SPEEDS)
    policy = HVLB_CC_B(alpha_max=1.0, alpha_step=0.25)

    def fresh(k):
        rng = np.random.default_rng(9000 + k)
        g = random_spg(n, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
        sched = Scheduler(tg, policy=policy, engine=engine,
                          backend=backend)
        return g, sched, sched.submit(g)

    # ---- processor failure: slowest ECU (gated) vs hottest (context) --
    us_slow = us_hot = float("inf")
    sv_slow: List[float] = []
    sv_hot: List[float] = []
    for k in range(reps):
        g, sched, p0 = fresh(k)
        plan, us = timed(sched.mark_failed, proc=7)
        us_slow = min(us_slow, us)
        sv_slow.append(_survival(plan, n))
        g, sched, p0 = fresh(k)
        hot = int(p0.schedule.proc[np.argmin(p0.schedule.start)])
        plan, us = timed(sched.mark_failed, proc=hot)
        us_hot = min(us_hot, us)
        sv_hot.append(_survival(plan, n))
    rows.append(row(f"exp9.P{P}.n{n}.proc_down_replan_us", us_slow,
                    float(np.mean(sv_slow))))
    rows.append(row(f"exp9.P{P}.n{n}.proc_down_worst_replan_us", us_hot,
                    float(np.mean(sv_hot))))

    # ---- link degradation / link loss --------------------------------
    us_deg = us_down = float("inf")
    sv_deg: List[float] = []
    sv_down: List[float] = []
    for k in range(reps):
        g, sched, p0 = fresh(k)
        plan, us = timed(sched.degrade, link="l8", factor=2.0)
        us_deg = min(us_deg, us)
        sv_deg.append(_survival(plan, n))
        g, sched, p0 = fresh(k)
        for link in sorted(tg.all_links()):
            try:
                plan, us = timed(sched.mark_failed, link=link)
            except InfeasibleScheduleError:
                # partition of the committed prefix — an infeasible
                # replan drops the session state, so rebuild and try
                # the next link
                sched = Scheduler(tg, policy=policy, engine=engine,
                                  backend=backend)
                sched.submit(g)
                continue
            us_down = min(us_down, us)
            sv_down.append(_survival(plan, n))
            break
    rows.append(row(f"exp9.P{P}.n{n}.link_degraded_replan_us", us_deg,
                    float(np.mean(sv_deg))))
    rows.append(row(f"exp9.P{P}.n{n}.link_down_replan_us", us_down,
                    float(np.mean(sv_down)) if sv_down else 0.0))

    # ---- compute spike (rides the update/task_rates path) -------------
    us_spk = float("inf")
    sv_spk: List[float] = []
    for k in range(reps):
        g, sched, p0 = fresh(k)
        sink = [t for t in range(g.n) if not g.succ[t]][-1]
        plan, us = timed(sched.degrade, task=sink, factor=2.0)
        us_spk = min(us_spk, us)
        sv_spk.append(_survival(plan, n))
    rows.append(row(f"exp9.P{P}.n{n}.compute_spike_replan_us", us_spk,
                    float(np.mean(sv_spk))))
    return rows
