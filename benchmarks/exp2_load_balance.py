"""Exp 2 (Fig. 8) — load balance (Eq. 24) vs number of tasks.

Lower LB is better (1.0 = perfectly balanced).  HVLB_CC must beat HSV_CC
for every task count and rate pattern.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, Scheduler,
                        load_balance, paper_topology, random_spg)

from .common import RATE_PATTERNS, row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    n_graphs = 100 if full else 20
    alpha_max = 20.0 if full else 5.0
    for rates in RATE_PATTERNS[:3]:
        tg = paper_topology(rates=rates)
        tag = "r" + "-".join(f"{x:g}" for x in rates)
        for n in (10, 20, 30, 40, 50):
            rng = np.random.default_rng(2000 + n)
            lbs = {k: [] for k in ("hsv", "hvlbA", "hvlbB")}
            us_tot = {k: 0.0 for k in lbs}
            for _ in range(n_graphs):
                g = random_spg(n, rng, ccr=1.0, tg=tg,
                               outdeg_constraint=True)
                # fresh session per timed row: per-call semantics, rows
                # stay comparable with earlier BENCH snapshots
                plan, us = timed(lambda: Scheduler(
                    tg, engine=engine).submit(g, HSV_CC()))
                lbs["hsv"].append(load_balance(plan.schedule))
                us_tot["hsv"] += us
                for policy, key in (
                        (HVLB_CC_A(alpha_max=alpha_max, alpha_step=0.05),
                         "hvlbA"),
                        (HVLB_CC_B(alpha_max=alpha_max, alpha_step=0.05),
                         "hvlbB")):
                    plan, us = timed(lambda p=policy: Scheduler(
                        tg, engine=engine).submit(g, p))
                    lbs[key].append(load_balance(plan.schedule))
                    us_tot[key] += us
            for key, vals in lbs.items():
                rows.append(row(f"exp2.{tag}.n{n}.{key}.lb_mean",
                                us_tot[key] / n_graphs,
                                float(np.mean(vals))))
    return rows
