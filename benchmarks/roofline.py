"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Reads the dry-run artifacts (experiments/dryrun/*.json) and derives, per
(arch x shape) on the single-pod mesh:

  compute term    = FLOPs / (chips x 197 TFLOP/s)
  memory term     = HBM bytes / (chips x 819 GB/s)
  collective term = per-chip collective bytes / (links x 50 GB/s ICI)
                    [+ DCN share / 6.25 GB/s on the multipod mesh]

FLOPs/HBM bytes are analytic (XLA cost_analysis counts scan bodies once —
the raw HLO numbers are reported alongside as *_hlo for transparency).
Collective bytes come from the compiled per-device SPMD program; in-loop
collectives are likewise counted once per scan (lower bound).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
       [--md experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES, cell_supported
from repro.planner.cost_model import HW, hbm_bytes, model_flops, total_flops

HWC = HW()


def cell_terms(arch: str, shape_name: str, rec: Dict) -> Dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    chips = rec["chips"]
    flops = total_flops(cfg, shape)
    mem = hbm_bytes(cfg, shape)
    coll_per_chip = sum(rec["collectives"].values())
    t_compute = flops / (chips * HWC.peak_flops)
    t_memory = mem / (chips * HWC.hbm_bw)
    t_coll = coll_per_chip / (HWC.ici_links * HWC.ici_bw)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / flops,
        "flops_hlo_per_chip": rec.get("cost", {}).get("flops", 0.0),
        "coll_bytes_per_chip": coll_per_chip,
        "roofline_bound_s": max(terms.values()),
        "roofline_frac": max(terms.values()) / sum(terms.values()),
    }


def load_all(dirpath: Path, mesh: str = "pod") -> List[Dict]:
    out = []
    for a in sorted(ARCHS):
        for s in SHAPES:
            p = dirpath / f"{a}__{s}__{mesh}.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if "skipped" in rec or "failed" in rec:
                continue
            out.append(cell_terms(a, s, rec))
    return out


def as_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOP ratio | bound (s) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
                 f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_bound_s']:.4g} |\n")
    return hdr + body


def run(full: bool = False) -> List[str]:
    rows = load_all(Path("experiments/dryrun"))
    out = []
    for r in rows:
        name = f"roofline.{r['arch']}.{r['shape']}"
        out.append(f"{name}.dominant,0.0,{r['dominant']}")
        out.append(f"{name}.bound_s,0.0,{r['roofline_bound_s']:.6g}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default="experiments/roofline.md")
    args = ap.parse_args()
    rows = load_all(Path(args.dir))
    md = as_markdown(rows)
    Path(args.md).write_text(md)
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print("dominant-term histogram:", doms)


if __name__ == "__main__":
    main()
