"""Exp 5 (Fig. 11, Table 4) — data precision vs input arrival rate with and
without the imprecise-computation model (HVLB_CC_IC vs HVLB_CC).

Imprecise tasks: the paper's scenario tasks (n2 external-stream transform,
n5 map-matching) plus every task with a usable schedule hole.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (HVLB_CC_IC, PAPER_COMP_EXP5, Scheduler, paper_spg,
                        paper_topology, precision_curve)

from .common import row, timed


def run(full: bool = False, engine: str = "compiled") -> List[str]:
    rows: List[str] = []
    g = paper_spg(comp=PAPER_COMP_EXP5)
    tg = paper_topology()
    sched = Scheduler(tg, policy=HVLB_CC_IC(alpha_max=3.0, period=150.0),
                      engine=engine)
    plan, us = timed(sched.submit, g)          # holes ride on the plan
    s = plan.schedule
    holes = {t: h for t, h in plan.holes.items() if np.isfinite(h)}
    rows.append(row("exp5.makespan", us, s.makespan))
    for t, h in sorted(holes.items()):
        rows.append(row(f"exp5.hole.n{t+1}", us, h))
    lams = np.round(np.arange(1.0, 2.01, 0.1), 2)
    tasks = sorted(set([1, 4]) | set(holes))   # n2, n5 + holed tasks
    for ic in (True, False):
        curves = precision_curve(s, tasks, lams, ic=ic)
        suffix = "ic" if ic else "noic"
        for t, curve in curves.items():
            for lam, p in zip(lams, curve):
                rows.append(row(f"exp5.{suffix}.n{t+1}.lam{lam:g}", us,
                                float(p) * 100.0))
    return rows
