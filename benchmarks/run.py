"""Benchmark driver — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--full] [--only exp1,exp3]

Emits ``name,us_per_call,derived`` CSV on stdout.  ``--full`` uses the
paper's sample sizes (100 graphs/point, 1000 DAGs for SFR, alpha to 20).
"""
from __future__ import annotations

import argparse
import importlib
import sys

MODULES = [
    "exp0_paper_example",
    "exp1_slr_speedup",
    "exp2_load_balance",
    "exp3_ccr",
    "exp4_sfr",
    "exp5_imprecise",
    "exp6_tpu_placement",
    "roofline",               # §Roofline summary rows from the dry-run
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sample sizes")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated exp prefixes to run")
    args = ap.parse_args()
    only = [x.strip() for x in args.only.split(",") if x.strip()]

    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ModuleNotFoundError as e:
            print(f"# skipped {mod_name}: {e}", file=sys.stderr)
            continue
        for r in mod.run(full=args.full):
            print(r)


if __name__ == "__main__":
    main()
