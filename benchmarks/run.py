"""Benchmark driver — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--full] [--only exp1,exp3]
                                 [--engine compiled|reference]
                                 [--backend auto|scalar|vector]
                                 [--json [PATH]]

Emits ``name,us_per_call,derived`` CSV on stdout.  ``--full`` uses the
paper's sample sizes (100 graphs/point, 1000 DAGs for SFR, alpha to 20).
``--backend`` selects the compiled engine's candidate-evaluation backend
for experiments that accept it (exp7 additionally times the scalar and
vector backends against each other regardless).  ``--json`` additionally
writes a machine-readable snapshot (default ``BENCH_sched.json``) with
every row plus an engine-vs-reference speedup probe on the exp1
alpha-sweep workload (n=50, alpha_max=5, step=0.05) so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import sys
import time

MODULES = [
    "exp0_paper_example",
    "exp1_slr_speedup",
    "exp2_load_balance",
    "exp3_ccr",
    "exp4_sfr",
    "exp5_imprecise",
    "exp6_tpu_placement",
    "exp7_engine_scaling",    # compiled-engine throughput scaling
    "exp8_session_api",       # incremental update + fleet submit_many
    "exp9_faults",            # fault-recovery latency + prefix survival
    "exp10_service",          # serving layer: coalescing + replan tail
    "roofline",               # §Roofline summary rows from the dry-run
]


def engine_speedup_probe(n_graphs: int = 3, backend=None) -> dict:
    """Time the exp1 alpha-sweep workload (n=50, alpha_max=5, step=0.05)
    on the reference and compiled paths and assert identical results."""
    import numpy as np

    from repro.core import HVLB_CC_A, Scheduler, paper_topology, random_spg

    tg = paper_topology()
    policy = HVLB_CC_A(alpha_max=5.0, alpha_step=0.05)
    ref_us = eng_us = 0.0
    for k in range(n_graphs):
        rng = np.random.default_rng(1050 + k)
        g = random_spg(50, rng, ccr=1.0, tg=tg, outdeg_constraint=True)
        t0 = time.perf_counter()
        ref = Scheduler(tg, policy=policy, engine="reference").submit(g).sweep
        t1 = time.perf_counter()
        eng = Scheduler(tg, policy=policy, engine="compiled",
                        backend=backend).submit(g).sweep
        t2 = time.perf_counter()
        assert np.array_equal(ref.alphas, eng.alphas)
        assert np.array_equal(ref.makespans, eng.makespans)
        assert ref.best_alpha == eng.best_alpha
        assert np.array_equal(ref.best.finish, eng.best.finish)
        ref_us += (t1 - t0) * 1e6
        eng_us += (t2 - t1) * 1e6
    return {
        "workload": "exp1 n=50 alpha_max=5 step=0.05 (x%d graphs)" % n_graphs,
        "reference_us_per_call": ref_us / n_graphs,
        "engine_us_per_call": eng_us / n_graphs,
        "speedup": ref_us / eng_us,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sample sizes")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated exp prefixes to run")
    ap.add_argument("--engine", type=str, default="compiled",
                    choices=["compiled", "reference"],
                    help="scheduler implementation for the experiments")
    ap.add_argument("--backend", type=str, default=None,
                    choices=["auto", "scalar", "vector", "pallas"],
                    help="candidate-evaluation backend for the compiled "
                         "engine (default: auto / $REPRO_SCHED_BACKEND); "
                         "pallas requires jax and runs the device kernel "
                         "(interpret mode off-TPU)")
    ap.add_argument("--json", type=str, nargs="?", const="BENCH_sched.json",
                    default=None, metavar="PATH",
                    help="also write a JSON snapshot (incl. the "
                         "engine-vs-reference speedup probe)")
    args = ap.parse_args()
    only = [x.strip() for x in args.only.split(",") if x.strip()]

    all_rows = []
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ModuleNotFoundError as e:
            print(f"# skipped {mod_name}: {e}", file=sys.stderr)
            continue
        kwargs = {"full": args.full}
        params = inspect.signature(mod.run).parameters
        if "engine" in params:
            kwargs["engine"] = args.engine
        if "backend" in params:
            kwargs["backend"] = args.backend
        for r in mod.run(**kwargs):
            all_rows.append(r)
            print(r)

    if args.json is not None:
        rows = []
        for r in all_rows:
            name, us, derived = r.split(",", 2)
            try:
                derived = float(derived)
            except ValueError:
                pass
            rows.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
        snapshot = {
            "engine": args.engine,
            "backend": args.backend,
            "full": args.full,
            "engine_vs_reference": engine_speedup_probe(
                backend=args.backend),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
