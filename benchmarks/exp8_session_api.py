"""Exp 8 (beyond-paper) — session-API economics: incremental ``update``
vs a full re-sweep, and fleet ``submit_many`` vs per-graph submission.

``update`` rows: one HVLB_CC(B) sweep is submitted for a mid-size graph,
then one sink operator's arrival rate drifts (Section 4.4 — the common
DSMS event: a sensor-rate change on one leaf query operator).  The
session uses ``probe_update`` to pick the drifted sink whose rank
influence stays local (drifts that cascade through every ancestor rank
legitimately re-simulate almost everything), then replays the memoized
decision-trace prefix and re-simulates only the suffix.  The row
compares that against a from-scratch submit of the modified graph under
the same pinned period — bit-identical results, asserted here.

``fleet`` rows: G independent serving graphs are scheduled against one
topology at the session's operating alpha (the online re-plan unit —
a full alpha sweep over a fleet union is dominated by the union's much
denser trace-flip structure and is *not* the fleet fast path).
Per-graph submission pays G compiles + G passes; ``submit_many`` joins
the graphs and runs one shared-link-state pass over the union.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import HVLB_CC_B, Scheduler, paper_topology, random_spg

from .common import row, timed


def run(full: bool = False, engine: str = "compiled",
        backend: Optional[str] = None) -> List[str]:
    rows: List[str] = []
    tg = paper_topology()

    # ---- incremental update vs full re-sweep -------------------------
    n = 200 if full else 120
    rng = np.random.default_rng(8000)
    g = random_spg(n, rng, ccr=1.0, tg=tg, max_in=3, max_out=6)
    policy = HVLB_CC_B(alpha_max=2.0, alpha_step=0.05)
    sched = Scheduler(tg, policy=policy, engine=engine, backend=backend)
    plan, submit_us = timed(sched.submit, g)
    rows.append(row("exp8.update.submit_us", submit_us, plan.makespan))

    # drift the sink whose 0.9x rate change invalidates the least trace
    sinks = [t for t in range(g.n) if not g.succ[t]]
    task = max(sinks,
               key=lambda t: sched.probe_update(task_rates={t: 0.9}))
    upd_us = full_us = float("inf")
    for _ in range(5 if full else 3):
        sched_k = Scheduler(tg, policy=policy, engine=engine,
                            backend=backend)
        plan_k = sched_k.submit(g)
        upd, us = timed(sched_k.update, task_rates={task: 0.9})
        upd_us = min(upd_us, us)
        fresh_sched = Scheduler(tg, policy=dataclasses.replace(
            policy, period=plan_k.period), engine=engine, backend=backend)
        fresh, us = timed(fresh_sched.submit, upd.graph)
        full_us = min(full_us, us)
        assert np.array_equal(upd.schedule.finish, fresh.schedule.finish)
    replayed = upd.replay.decisions_replayed
    total = replayed + upd.replay.decisions_simulated
    rows.append(row("exp8.update.incremental_us", upd_us,
                    full_us / upd_us))               # derived = speedup
    rows.append(row("exp8.update.full_resweep_us", full_us,
                    100.0 * replayed / max(1, total)))  # % replayed

    # ---- fleet submit_many vs per-graph submission --------------------
    # Fleet scale: many small query graphs (the DSMS register-once shape),
    # scheduled at the session's operating alpha (the online re-plan
    # unit).  Min-of-k timing: the per-submit fixed costs the union
    # amortizes are small enough that scheduler noise would swamp a
    # single-shot measurement.
    n_fleet = 32 if full else 24
    graphs = [random_spg(int(rng.integers(8, 17)), rng, ccr=1.0, tg=tg,
                         max_in=3, max_out=6) for _ in range(n_fleet)]
    fleet_policy = HVLB_CC_B(alpha_max=0.0, alpha_step=0.05)

    def per_graph():
        sched_pg = Scheduler(tg, policy=fleet_policy, engine=engine,
                             backend=backend)
        return [sched_pg.submit(gk) for gk in graphs]

    per_us = many_us = float("inf")
    for _ in range(5 if full else 3):
        plans, us = timed(per_graph)
        per_us = min(per_us, us)
        fleet, us = timed(Scheduler(tg, policy=fleet_policy, engine=engine,
                                    backend=backend).submit_many, graphs)
        many_us = min(many_us, us)
    for k in range(n_fleet):
        fleet.subschedule(k)                 # slices stay addressable
    rows.append(row("exp8.fleet.per_graph_us", per_us,
                    float(sum(p.makespan for p in plans))))
    rows.append(row("exp8.fleet.submit_many_us", many_us,
                    per_us / many_us))               # derived = speedup
    return rows
