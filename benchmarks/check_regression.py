"""Compare a fresh benchmark snapshot against the committed baseline.

  python benchmarks/check_regression.py BASELINE FRESH \\
      --row exp7.P8.n500.schedule_us [--row ...] [--max-regress 0.20] \\
      [--min-derived exp7.P8.n100.ref_schedule_us:2.0 ...] \\
      [--max-derived exp7.P8.n500.cold_submit_us:1.6 ...]

Exits 1 (for CI) if any watched row's ``us_per_call`` regressed by
more than ``--max-regress`` (fraction) relative to the baseline.  A
*broken gate* — a snapshot file that is missing/unreadable, or a gated
row name absent from a snapshot — exits 2 with a one-line message
naming exactly what is missing: a silently dropped watchdog row is
itself a regression, and a misconfigured gate must not read as either
"pass" or "perf regressed".

``--row`` compares absolute microseconds across snapshots, which only
makes sense on comparable hardware; ``--min-derived`` /
``--max-derived`` gate a row's ``derived`` value of the *fresh* snapshot
alone (e.g. the exp7 ``ref_schedule_us`` rows, whose derived field is
the same-machine engine-vs-reference speedup, or ``cold_submit_us``,
whose derived field is the same-run cold/warm ratio), so they stay
meaningful on CI runners whose absolute speed differs from the machine
that recorded the baseline.
"""
from __future__ import annotations

import argparse
import json
import sys


class GateConfigError(Exception):
    """The gate itself is broken (missing file/row) — exit 2, not 1."""


def load_rows(path: str, which: str) -> dict:
    try:
        with open(path) as f:
            snap = json.load(f)
    except FileNotFoundError:
        raise GateConfigError(
            f"{which} snapshot {path!r} does not exist — run "
            f"'python -m benchmarks.run --json {path}' first") from None
    except json.JSONDecodeError as e:
        raise GateConfigError(
            f"{which} snapshot {path!r} is not valid JSON: {e}") from None
    try:
        return {r["name"]: (float(r["us_per_call"]), r["derived"])
                for r in snap["rows"]}
    except (KeyError, TypeError) as e:
        raise GateConfigError(
            f"{which} snapshot {path!r} is malformed "
            f"(missing {e}): expected {{'rows': [{{'name', "
            f"'us_per_call', 'derived'}}, ...]}}") from None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed snapshot (BENCH_sched.json)")
    ap.add_argument("fresh", help="freshly produced snapshot")
    ap.add_argument("--row", action="append", default=[],
                    metavar="NAME", help="row name to watch (repeatable)")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="max tolerated fractional latency increase")
    ap.add_argument("--min-derived", action="append", default=[],
                    metavar="NAME:VALUE",
                    help="fail if the fresh row's derived value is below "
                         "VALUE (machine-independent gate, repeatable)")
    ap.add_argument("--max-derived", action="append", default=[],
                    metavar="NAME:VALUE",
                    help="fail if the fresh row's derived value is above "
                         "VALUE (machine-independent gate, repeatable)")
    args = ap.parse_args()
    if not args.row and not args.min_derived and not args.max_derived:
        ap.error("nothing to check: pass --row, --min-derived and/or "
                 "--max-derived")

    # Load the snapshots independently so one bad file does not mask
    # problems with the other (or with the gate specs below): the
    # exit-2 path must show the FULL list of broken specs in one run.
    failed = broken = False
    base = fresh = None
    for attr, which in (("baseline", "baseline"), ("fresh", "fresh")):
        try:
            rows = load_rows(getattr(args, attr), which)
        except GateConfigError as e:
            print(f"GATE BROKEN: {e}")
            broken = True
            continue
        if which == "baseline":
            base = rows
        else:
            fresh = rows
    for name in args.row:
        # --row compares across snapshots, so it needs both; the
        # missing-file message already printed above
        if base is None or fresh is None:
            continue
        if name not in base or name not in fresh:
            which = "baseline" if name not in base else "fresh"
            print(f"GATE BROKEN --row {name}: row missing from the "
                  f"{which} snapshot")
            broken = True
            continue
        ratio = fresh[name][0] / base[name][0]
        status = "FAIL" if ratio > 1.0 + args.max_regress else "ok"
        print(f"{status} {name}: {base[name][0]:.1f}us -> "
              f"{fresh[name][0]:.1f}us "
              f"({ratio:.2f}x, limit {1.0 + args.max_regress:.2f}x)")
        failed |= status == "FAIL"
    for bound_specs, below, kind, flag in (
            (args.min_derived, True, "floor", "--min-derived"),
            (args.max_derived, False, "ceiling", "--max-derived")):
        for spec in bound_specs:
            name, _, bound = spec.rpartition(":")
            if not name or not bound:
                print(f"GATE BROKEN {flag} {spec!r}: expected NAME:VALUE")
                broken = True
                continue
            try:
                limit = float(bound)
            except ValueError:
                print(f"GATE BROKEN {flag} {spec!r}: bound {bound!r} is "
                      f"not a number")
                broken = True
                continue
            if fresh is None:           # derived gates only need fresh
                continue
            if name not in fresh:
                print(f"GATE BROKEN {flag} {name}: row missing from the "
                      f"fresh snapshot")
                broken = True
                continue
            value = float(fresh[name][1])
            bad = value < limit if below else value > limit
            status = "FAIL" if bad else "ok"
            print(f"{status} {name}: derived {value:.2f} ({kind} {bound})")
            failed |= bad
    if broken:
        return 2
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
