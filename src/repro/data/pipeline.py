"""Deterministic synthetic token pipeline.

Step-indexed and host-shardable: ``batch_for_step(step)`` is a pure
function of (seed, step), so any host can regenerate any shard — which is
what makes checkpoint-restart and elastic resharding trivial (no data
cursor state to save beyond the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # markov-chain-ish synthetic text: token t+1 depends on token t
    structure: float = 0.7          # fraction of deterministic transitions


class SyntheticTokenPipeline:
    """Generates (tokens, labels) batches with learnable structure
    (next-token = affine function of current token, noise elsewhere) so a
    real training run shows decreasing loss."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg

    def batch_for_step(self, step: int,
                       host_index: int = 0, host_count: int = 1
                       ) -> Dict[str, np.ndarray]:
        B = self.shape.global_batch // host_count
        S = self.shape.seq_len
        V = self.cfg.vocab
        rng = np.random.default_rng(
            (self.data_cfg.seed, step, host_index))
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        noise = rng.random((B, S))
        rand_next = rng.integers(0, V, size=(B, S))
        for t in range(S):
            det = (toks[:, t] * 31 + 7) % V
            toks[:, t + 1] = np.where(noise[:, t] < self.data_cfg.structure,
                                      det, rand_next[:, t])
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.embed_inputs:
            emb = rng.standard_normal((B, S, self.cfg.d_model),
                                      np.float32).astype(np.float32)
            out = {"embeds": emb, "labels": out["labels"]}
        if self.cfg.vision_prefix:
            out["vision_embeds"] = rng.standard_normal(
                (B, S // 4, self.cfg.d_model)).astype(np.float32) * 0.02
        return out

    def device_batch(self, step: int, shardings=None) -> Dict[str, jax.Array]:
        host = self.batch_for_step(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(jnp.asarray(v), shardings[k])
                for k, v in host.items()}
