"""Streaming DSMS serving engine — the paper's application layer on top of
the model runtime.

Queries are registered ahead of time (the DSMS principle: register once,
execute continuously); each query is an operator chain over the decoded
model output (the "stream").  The engine:

  1. builds the serving SPG (backbone + query operators),
  2. statically schedules it with HVLB_CC (B) onto the slice topology
     (HSV_CC cannot order these multi-sink graphs — Section 3.2),
  3. runs batched decode steps, executing query operators according to
     the static schedule,
  4. supports imprecise-computation queries: each operator has a mandatory
     function and an optional refinement that only runs inside its
     schedule hole (HVLB_CC_IC, Section 4.4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.core import schedule_holes, schedule_hvlb_cc
from repro.core.graph import SPG
from repro.models import model as M
from repro.planner import serving_query_graph, tpu_slice_topology


@dataclasses.dataclass
class Query:
    name: str
    mandatory: Callable[[jax.Array], Any]
    optional: Optional[Callable[[Any], Any]] = None
    # estimated cost ratio of optional part vs mandatory (for IC planning)
    optional_ratio: float = 1.0


@dataclasses.dataclass
class StepResult:
    tokens: np.ndarray
    query_outputs: Dict[str, Any]
    precise: Dict[str, bool]


class DSMSEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_seq: int, n_slices: int = 4):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.queries: List[Query] = []
        self.cache = M.init_cache(cfg, batch_size, max_seq)
        self.pos = 0
        self._step = jax.jit(
            lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))
        self.topology = tpu_slice_topology(n_slices=n_slices,
                                           chips_per_slice=4, pods=1)
        self.plan = None
        self.holes: Dict[int, float] = {}

    def register(self, q: Query) -> None:
        """Register a continuous query (before streaming starts)."""
        self.queries.append(q)
        self._replan()

    def _replan(self) -> None:
        shape = dataclasses.replace(SHAPES["decode_32k"],
                                    global_batch=self.batch,
                                    seq_len=self.max_seq)
        g = serving_query_graph(self.cfg, shape,
                                n_queries=max(1, len(self.queries)))
        res = schedule_hvlb_cc(g, self.topology, variant="B",
                               alpha_max=2.0, alpha_step=0.1)
        self.plan = res.best
        self.holes = schedule_holes(self.plan)
        # map query q to its first operator node (backbone is nodes [0..k))
        n_backbone = g.n - 3 * max(1, len(self.queries))
        self._query_nodes = {qi: n_backbone + 3 * qi
                             for qi in range(len(self.queries))}

    def _has_hole(self, qi: int, q: Query) -> bool:
        node = self._query_nodes.get(qi)
        if node is None or self.plan is None:
            return False
        hole = self.holes.get(node, 0.0)
        g = self.plan.graph
        mand = g.comp(node, int(self.plan.proc[node]), self.topology.rates)
        return hole >= q.optional_ratio * mand

    def step(self, tokens: np.ndarray) -> StepResult:
        """Feed one token per stream; run queries per the static plan."""
        t = jnp.asarray(tokens.reshape(self.batch, 1), jnp.int32)
        pos = jnp.full((self.batch,), self.pos, jnp.int32)
        logits, self.cache = self._step(self.params, self.cache, t, pos)
        self.pos += 1
        out_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        outputs: Dict[str, Any] = {}
        precise: Dict[str, bool] = {}
        for qi, q in enumerate(self.queries):
            res = q.mandatory(logits)
            ok = False
            if q.optional is not None and self._has_hole(qi, q):
                res = q.optional(res)
                ok = True
            outputs[q.name] = res
            precise[q.name] = ok or q.optional is None
        return StepResult(out_tok, outputs, precise)
