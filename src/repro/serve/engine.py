"""Streaming DSMS serving engine — the paper's application layer on top of
the model runtime.

Queries are registered ahead of time (the DSMS principle: register once,
execute continuously); each query is an operator chain over the decoded
model output (the "stream").  The engine:

  1. builds the serving SPG (backbone + query operators),
  2. statically schedules it through a long-lived
     :class:`repro.core.Scheduler` session with the imprecise-computation
     policy ``HVLB_CC_IC`` (HSV_CC cannot order these multi-sink graphs —
     Section 3.2); the plan carries the schedule holes directly,
  3. runs batched decode steps, executing query operators according to
     the static schedule,
  4. supports imprecise-computation queries: each operator has a mandatory
     function and an optional refinement that only runs inside its
     schedule hole (HVLB_CC_IC, Section 4.4).

Registration is O(1): ``register()`` only marks the plan dirty, and the
schedule is recomputed once — lazily, on the first ``step()`` (or an
explicit ``ensure_plan()``) after any number of registrations.  ``replans``
counts the actual scheduler invocations, pinned by the regression test in
``tests/test_session_api.py``.  Task-time drift re-plans go through
``Scheduler.update`` (:meth:`retime`), which replays only the affected
suffix of the decision trace.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.core import HVLB_CC_IC, Scheduler
from repro.core.graph import SPG
from repro.models import model as M
from repro.planner import serving_query_graph, tpu_slice_topology


@dataclasses.dataclass
class Query:
    name: str
    mandatory: Callable[[jax.Array], Any]
    optional: Optional[Callable[[Any], Any]] = None
    # estimated cost ratio of optional part vs mandatory (for IC planning)
    optional_ratio: float = 1.0


@dataclasses.dataclass
class StepResult:
    tokens: np.ndarray
    query_outputs: Dict[str, Any]
    precise: Dict[str, bool]
    # Per-query precision loss report (Eq. 22 shape): 1.0 when the optional
    # refinement ran (or the query has none), else the mandatory-only
    # fraction mand/(mand + opt).  Appended with a default so positional
    # construction by older callers keeps working.
    precision: Dict[str, float] = dataclasses.field(default_factory=dict)


class DSMSEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_seq: int, n_slices: int = 4,
                 backend: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.queries: List[Query] = []
        self.cache = M.init_cache(cfg, batch_size, max_seq)
        self.pos = 0
        self._step = jax.jit(
            lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))
        self.topology = tpu_slice_topology(n_slices=n_slices,
                                           chips_per_slice=4, pods=1)
        # backend: candidate-evaluation backend for the static scheduler
        # ("auto" picks the (P,)-vector path on wide slice topologies;
        # "pallas" opts into the device kernel — see DESIGN.md §5)
        self.scheduler = Scheduler(
            self.topology, policy=HVLB_CC_IC(alpha_max=2.0, alpha_step=0.1),
            backend=backend)
        self.plan = None
        self.holes: Dict[int, float] = {}
        self.replans = 0                    # scheduler invocations (test-pinned)
        self._dirty = True
        self._graph: Optional[SPG] = None
        self._query_nodes: Dict[int, int] = {}

    def register(self, q: Query) -> None:
        """Register a continuous query (before streaming starts).

        O(1): the schedule is recomputed lazily on the next ``step()`` —
        registering Q queries costs one re-plan, not Q.
        """
        self.queries.append(q)
        self._dirty = True

    def ensure_plan(self) -> None:
        """Re-plan if the query set changed since the last schedule."""
        if not self._dirty:
            return
        shape = dataclasses.replace(SHAPES["decode_32k"],
                                    global_batch=self.batch,
                                    seq_len=self.max_seq)
        g = serving_query_graph(self.cfg, shape,
                                n_queries=max(1, len(self.queries)))
        plan = self.scheduler.submit(g)
        self.replans += 1
        self._graph = g
        self.plan = plan.schedule
        self.holes = plan.holes
        # query q -> its first operator node, from the graph's own mapping
        self._query_nodes = {qi: g.query_ops[qi][0]
                             for qi in range(len(self.queries))}
        self._dirty = False

    def retime(self, task_rates) -> None:
        """Re-plan after task computation-time drift (Section 4.4's varying
        arrival rates) via the incremental ``Scheduler.update`` path.

        Accepts either one ``{task: factor}`` dict or a sequence of such
        dicts (a pending batch of drift events, oldest first) — the batch
        is folded into one combined suffix replay, bit-identical to
        applying the events one ``retime`` at a time.
        """
        self.ensure_plan()
        plan = self.scheduler.update(task_rates=task_rates,
                                     graph=self._graph)
        self._adopt(plan)

    def mark_failed(self, *, proc: Optional[int] = None,
                    link: Optional[str] = None) -> None:
        """Report a failed processor or link; replans the serving graph.

        Graceful IC degradation: the replan typically leaves fewer/smaller
        schedule holes, so optional query refinements stop running and the
        per-query ``StepResult.precision`` drops below 1.0 — the engine
        keeps serving rather than failing
        (:class:`repro.core.InfeasibleScheduleError` still propagates when
        no feasible placement remains at all).
        """
        self.ensure_plan()
        self._adopt(self.scheduler.mark_failed(proc=proc, link=link,
                                               graph=self._graph))

    def degrade(self, *, link: Optional[str] = None,
                task: Optional[int] = None, factor: float) -> None:
        """Report a degraded link (or a task compute spike); replans."""
        self.ensure_plan()
        self._adopt(self.scheduler.degrade(link=link, task=task,
                                           factor=factor,
                                           graph=self._graph))

    def restore(self, *, proc: Optional[int] = None,
                link: Optional[str] = None) -> None:
        """Clear a previously reported fault; replans from scratch."""
        self.ensure_plan()
        self._adopt(self.scheduler.restore(proc=proc, link=link,
                                           graph=self._graph))

    def _adopt(self, plan) -> None:
        self.replans += 1
        self._graph = plan.graph
        self.plan = plan.schedule
        self.holes = plan.holes

    def _has_hole(self, qi: int, q: Query) -> bool:
        node = self._query_nodes.get(qi)
        if node is None or self.plan is None:
            return False
        hole = self.holes.get(node, 0.0)
        g = self.plan.graph
        mand = g.comp(node, int(self.plan.proc[node]), self.topology.rates)
        return hole >= q.optional_ratio * mand

    def step(self, tokens: np.ndarray) -> StepResult:
        """Feed one token per stream; run queries per the static plan."""
        self.ensure_plan()
        t = jnp.asarray(tokens.reshape(self.batch, 1), jnp.int32)
        pos = jnp.full((self.batch,), self.pos, jnp.int32)
        logits, self.cache = self._step(self.params, self.cache, t, pos)
        self.pos += 1
        out_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        outputs: Dict[str, Any] = {}
        precise: Dict[str, bool] = {}
        precision: Dict[str, float] = {}
        for qi, q in enumerate(self.queries):
            res = q.mandatory(logits)
            ok = False
            if q.optional is not None and self._has_hole(qi, q):
                res = q.optional(res)
                ok = True
            outputs[q.name] = res
            precise[q.name] = ok or q.optional is None
            precision[q.name] = 1.0 if precise[q.name] \
                else 1.0 / (1.0 + q.optional_ratio)
        return StepResult(out_tok, outputs, precise, precision)
