from .engine import DSMSEngine, Query
