"""Fault-tolerant checkpointing with elastic resharding.

Layout: ``<dir>/step_<n>/<flat.key.path>.npy`` plus ``manifest.json``.
Writes go to a temp dir and are atomically renamed, so a crash mid-save
never corrupts the latest checkpoint (restart picks the previous one).

Restore reshards to whatever mesh/shardings the caller passes — restoring
a 4-way checkpoint onto 2 devices (or 512) is the elastic-scaling path;
combined with the step-indexed data pipeline, a restart is exact.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any
SEP = "::"


def _flatten(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Tree) -> Path:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {}
    for key, arr in flat.items():
        fname = key.replace("/", "_") + ".npy"
        np.save(tmp / fname, arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "leaves": manifest}, indent=1))
    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Tree,
            shardings: Optional[Tree] = None) -> Tree:
    """Load into the structure of ``like``; reshard onto ``shardings``
    (tree of NamedSharding) if given — any mesh size works."""
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    flat_sh = _flatten_aux(shardings) if shardings is not None else {}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_with_path:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.load(d / manifest[key]["file"])
        if key in flat_sh and flat_sh[key] is not None:
            out.append(jax.device_put(arr, flat_sh[key]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _flatten_aux(tree: Tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: x is None or hasattr(x, "spec"))[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat
