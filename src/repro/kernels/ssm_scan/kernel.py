"""Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation: the recurrence is carried in VMEM scratch over a grid
whose sequence axis is innermost-sequential; each program instance owns a
(channel-block x state) tile of ``h`` so the VPU processes (block_d, N)
elementwise updates while the sequence advances.  deltaA = exp(dt*A) is
computed on the fly per tile — the (B,S,Di,N) tensor never exists in HBM
(that blow-up is exactly what makes a naive TPU port of the CUDA scan
infeasible).

Grid: (batch, Di/block_d, S/block_s); the per-step inner loop runs
``block_s`` sequential VPU updates on resident tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 512
DEFAULT_BLOCK_S = 256


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                 block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)        # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        da = jnp.exp(dt_t[:, None] * a)                   # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = (h @ c_t).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


def selective_scan_kernel(x: jax.Array, dt: jax.Array, A: jax.Array,
                          Bm: jax.Array, Cm: jax.Array, *,
                          block_d: int = DEFAULT_BLOCK_D,
                          block_s: int = DEFAULT_BLOCK_S,
                          interpret: bool = False) -> jax.Array:
    """x, dt (B,S,Di); A (Di,N); Bm, Cm (B,S,N) -> y (B,S,Di)."""
    B, S, Di = x.shape
    N = A.shape[1]
    block_d = min(block_d, Di)
    block_s = min(block_s, S)
    assert Di % block_d == 0 and S % block_s == 0
    grid = (B, Di // block_d, S // block_s)

    return pl.pallas_call(
        functools.partial(_scan_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((block_d, N), lambda b, d, s: (d, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d),
                               lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
