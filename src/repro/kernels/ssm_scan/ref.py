"""Pure-jnp oracle for the Mamba-1 selective scan.

h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
y_t = h_t @ C_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array) -> jax.Array:
    """x, dt (B,S,Di); A (Di,N); Bm, Cm (B,S,N) -> y (B,S,Di)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    deltaA = jnp.exp(dtf[..., None] * Af)                 # (B,S,Di,N)
    dBx = (dtf * xf)[..., None] * Bf[:, :, None, :]       # (B,S,Di,N)

    def step(h, inp):
        da, bx, c = inp
        h = da * h + bx
        return h, jnp.einsum("ben,bn->be", h, c)

    B, S, Di = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(deltaA, 1, 0),
                                    jnp.moveaxis(dBx, 1, 0),
                                    jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
