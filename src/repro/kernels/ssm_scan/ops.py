"""jit'd public wrapper for the selective scan."""
from __future__ import annotations

import functools

import jax

from .kernel import selective_scan_kernel
from .ref import selective_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def selective_scan(x, dt, A, Bm, Cm, use_kernel: bool = True):
    if use_kernel and _on_tpu():
        return selective_scan_kernel(x, dt, A, Bm, Cm)
    return selective_scan_ref(x, dt, A, Bm, Cm)
