"""FlashAttention forward as a Pallas TPU kernel.

Schedule (TPU adaptation — not a CUDA port): the grid walks
(batch*kv_head, group, q_block, kv_block) with the kv_block axis
INNERMOST and sequential; online-softmax statistics (m, l) and the output
accumulator live in VMEM scratch across kv iterations.  Block shapes are
MXU-aligned (multiples of 128 on the S dims, head_dim lanes); HBM->VMEM
movement is expressed entirely through BlockSpec index maps so each tile
is streamed once per use.

Causal handling: fully-masked kv blocks are skipped via ``pl.when`` on
the block indices (no wasted MXU work past the diagonal); the diagonal
block applies an elementwise mask.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks strictly above the diagonal (causal)
    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, :, :].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q (B, Hq, S, d), k/v (B, Hkv, S, d) -> (B, Hq, S, d)."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    bh = B * Hkv
    qr = q.reshape(bh, G, S, d)
    kr = k.reshape(bh, S, d)
    vr = v.reshape(bh, S, d)
    grid = (bh, G, S // block_q, S // block_k)
    scale = 1.0 / math.sqrt(d)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, block_q=block_q,
                          block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, G, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # l: running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, d)
