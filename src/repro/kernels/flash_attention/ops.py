"""jit'd public wrapper: Pallas kernel on TPU, interpret-mode kernel or
jnp oracle elsewhere."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_kernel
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    use_kernel: bool = True) -> jax.Array:
    """q (B, Hq, S, d), k/v (B, Hkv, S, d) -> (B, Hq, S, d)."""
    if use_kernel and _on_tpu():
        return flash_attention_kernel(q, k, v, causal=causal)
    return attention_ref(q, k, v, causal=causal)
