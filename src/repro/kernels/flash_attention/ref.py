"""Pure-jnp oracle for blocked (flash) attention with GQA + causal mask."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q (B, Hq, S, d), k/v (B, Hkv, S, d) -> (B, Hq, S, d).

    GQA: Hq must be a multiple of Hkv; query head h reads kv head
    ``h // (Hq // Hkv)``.  Accumulation in fp32.
    """
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, d).astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg * scale,
                        k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, d).astype(q.dtype)
