"""TGFF-style random stream-processing-graph generator (Section 5.2).

Parameters follow the paper: max in-degree 2, max out-degree 3, at least two
entry and two exit nodes, task weights drawn so per-processor computation
times vary with the execution rates, and edge communication volumes scaled
to a target CCR (communication-to-computation ratio).

``outdeg_constraint=True`` additionally enforces ``outd(pred) >= outd(succ)``
— the restricted family that HSV_CC can always schedule (used by
Experiments 1-3); Experiment 4 turns it off to measure SFR.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .graph import SPG
from .topology import Topology


def random_spg(n: int, rng: np.random.Generator, *, max_in: int = 2,
               max_out: int = 3, min_entries: int = 2, min_exits: int = 2,
               ccr: float = 1.0, tg: Optional[Topology] = None,
               outdeg_constraint: bool = False,
               w_lo: float = 5.0, w_hi: float = 25.0) -> SPG:
    """Random layered DAG with the paper's degree constraints."""
    for _attempt in range(200):
        g = _try_random(n, rng, max_in, max_out, min_entries, min_exits)
        if g is None:
            continue
        edges, depth_ok = g
        if outdeg_constraint:
            edges = _enforce_outdeg(n, edges)
            if edges is None or not _check_outdeg(n, edges):
                continue
        weights = rng.uniform(w_lo, w_hi, size=n)
        spg = SPG(n=n, edges=edges, weights=weights, name=f"tgff_{n}")
        _assign_tpl(spg, rng, ccr, tg)
        return spg
    raise RuntimeError("could not generate a graph with the constraints")


def _try_random(n, rng, max_in, max_out, min_entries, min_exits):
    n_levels = max(2, int(round(np.sqrt(n))) + rng.integers(0, 2))
    levels = np.sort(rng.integers(0, n_levels, size=n))
    levels[:min_entries] = 0                      # guarantee entries
    levels[-min_exits:] = n_levels - 1            # guarantee exits
    edges = []
    ind = np.zeros(n, dtype=int)
    outd = np.zeros(n, dtype=int)
    order = np.arange(n)
    for j in order:
        if levels[j] == 0:
            continue
        cands = [i for i in order
                 if levels[i] < levels[j] and outd[i] < max_out]
        if not cands:
            return None
        k = int(rng.integers(1, max_in + 1))
        k = min(k, len(cands))
        for i in rng.choice(cands, size=k, replace=False):
            edges.append((int(i), int(j)))
            ind[j] += 1
            outd[i] += 1
    # every non-exit node must reach somewhere: attach dangling nodes
    for i in order:
        if levels[i] < levels.max() and outd[i] == 0:
            cands = [j for j in order
                     if levels[j] > levels[i] and ind[j] < max_in]
            if cands:
                j = int(rng.choice(cands))
                edges.append((int(i), j))
                ind[j] += 1
                outd[i] += 1
                continue
            # Every later node is at full in-degree (common once n is in
            # the hundreds: earlier repairs saturate the scarce top
            # levels).  Steal an in-slot from a predecessor that can spare
            # an out-edge — every degree cap is preserved.
            swaps = [(ii, j) for (ii, j) in edges
                     if levels[j] > levels[i] and outd[ii] > 1]
            if not swaps:
                return None
            ii, j = swaps[int(rng.integers(len(swaps)))]
            edges.remove((ii, j))
            outd[ii] -= 1
            edges.append((int(i), int(j)))
            outd[i] += 1
    return edges, True


def _enforce_outdeg(n, edges):
    """Repair pass: drop out-edges of violating successors until
    ``outd(pred) >= outd(succ)`` holds on every edge (Experiment 1-3
    graph family).  Edges are only removed when the sink keeps ind >= 1."""
    edges = list(edges)
    for _ in range(10 * len(edges) + 10):
        outd = np.zeros(n, dtype=int)
        ind = np.zeros(n, dtype=int)
        for (i, j) in edges:
            outd[i] += 1
            ind[j] += 1
        bad = [(i, j) for (i, j) in edges if outd[i] < outd[j]]
        if not bad:
            return edges
        bad.sort(key=lambda e: outd[e[1]] - outd[e[0]], reverse=True)
        i, j = bad[0]
        # shrink outd(j): remove one of j's out-edges whose sink keeps ind>1
        cands = [(jj, k) for (jj, k) in edges if jj == j and ind[k] > 1]
        if cands:
            cands.sort(key=lambda e: -ind[e[1]])
            edges.remove(cands[0])
        elif ind[j] > 1:
            edges.remove((i, j))
        else:
            return None
    return None


def _check_outdeg(n, edges):
    outd = np.zeros(n, dtype=int)
    for (i, j) in edges:
        outd[i] += 1
    return all(outd[i] >= outd[j] for (i, j) in edges)


def _assign_tpl(spg: SPG, rng: np.random.Generator, ccr: float,
                tg: Optional[Topology]) -> None:
    """Draw edge volumes so mean comm time / mean comp time == CCR."""
    if tg is not None:
        mean_comp = float(np.mean([
            [spg.comp(i, p, tg.rates) for p in range(tg.n_procs)]
            for i in range(spg.n)]))
        mean_speed = float(np.mean([tg.proc_speed(p)
                                    for p in range(tg.n_procs)]))
    else:
        mean_comp = float(spg.weights.mean())
        mean_speed = 1.0
    target_tpl = ccr * mean_comp * mean_speed
    for e in spg.edges:
        spg.tpl[e] = float(rng.uniform(0.5, 1.5) * target_tpl)
