"""Experimental metrics: SLR (Eq. 22), speedup (Eq. 23), LB (Eqs. 24-25),
SFR (Eq. 26)."""
from __future__ import annotations

from .scheduler import Schedule


def slr(s: Schedule) -> float:
    """Schedule-length ratio: makespan over the min-comp critical path."""
    g, tg = s.graph, s.topology
    cp = g.critical_path_min_comp(tg.rates, tg.n_procs)
    return s.makespan / cp


def speedup(s: Schedule) -> float:
    """Min sequential execution time over makespan."""
    g, tg = s.graph, s.topology
    seq = min(sum(g.comp(i, p, tg.rates) for i in range(g.n))
              for p in range(tg.n_procs))
    return seq / s.makespan


def load_balance(s: Schedule) -> float:
    """LB = makespan / Avg (lower is better; 1.0 is perfectly balanced)."""
    loads = s.proc_loads()
    avg = loads.sum() / s.topology.n_procs
    return s.makespan / avg


def sfr(failures: int, total: int) -> float:
    """Scheduling failure rate, percent (Eq. 26)."""
    return 100.0 * failures / total
