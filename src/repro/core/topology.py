"""Heterogeneous network topology ``TG = <P, S, L>`` (Section 2.3).

Processors are connected by switches/gateways through links of differing
speeds; between two processors there may be several routes, each a sequence
of links.  Route speed is the average over routes of the minimum link speed
(Eqs. 3-4); a processor's data-transfer speed is the average route speed to
every other processor (Eq. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

Route = Tuple[str, ...]


@dataclasses.dataclass
class Topology:
    """Heterogeneous processors + multi-route contended network."""

    proc_names: List[str]
    rates: np.ndarray                       # execution rate mu per processor
    link_speed: Dict[str, float]            # link name -> speed
    routes: Dict[Tuple[int, int], List[Route]]  # (src,dst) -> route list
    # Link-level message times (CTML, Eq. 15) quantization.  The paper's
    # Gantt charts schedule messages in integer time slots ("round"); rank
    # computation always stays analytic/exact (Table 2 is fractional).
    ctml_mode: str = "exact"                # "exact" | "round" | "ceil"

    def __post_init__(self) -> None:
        self.rates = np.asarray(self.rates, dtype=float)
        self.n_procs = len(self.proc_names)
        # make routes symmetric if only one direction was given
        for (a, b), rr in list(self.routes.items()):
            if (b, a) not in self.routes:
                self.routes[(b, a)] = [tuple(reversed(r)) for r in rr]
        # Derived quantities are pure functions of the (frozen-by-convention)
        # link/route tables, so compute them once instead of re-running
        # np.mean over every route on every call.
        self._all_links: List[str] = sorted(self.link_speed)
        self._link_index: Dict[str, int] = {
            l: k for k, l in enumerate(self._all_links)}
        self._route_speed: Dict[Tuple[int, int], float] = {
            pair: float(np.mean([self.route_min_speed(r) for r in rr]))
            for pair, rr in self.routes.items()}
        self._proc_speed: Dict[int, float] = {}
        for src in range(self.n_procs):
            others = [d for d in range(self.n_procs) if d != src]
            if all((src, d) in self._route_speed for d in others):
                self._proc_speed[src] = float(np.mean(
                    [self._route_speed[(src, d)] for d in others]))

    # ------------------------------------------------------------------
    def ctml(self, tpl: float, link: str) -> float:
        """Communication time of a message on one link (Eq. 15).

        A non-positive speed (a down link in a fault-masked view, see
        :func:`~.faults.apply_to_topology`) yields ``inf`` rather than a
        ZeroDivisionError — the link is simply unusable.
        """
        sp = self.link_speed[link]
        if sp <= 0.0:
            return float("inf")
        t = tpl / sp
        if self.ctml_mode == "round":
            return float(round(t))
        if self.ctml_mode == "ceil":
            return float(np.ceil(t))
        return t

    def route_min_speed(self, route: Route) -> float:
        """Speed of a single route = slowest link on it (Eq. 4 inner min)."""
        return min(self.link_speed[l] for l in route)

    def route_speed(self, src: int, dst: int) -> float:
        """Average of per-route min speeds between src and dst (Eqs. 3-4)."""
        cached = self._route_speed.get((src, dst))
        if cached is not None:
            return cached
        rr = self.routes[(src, dst)]
        return float(np.mean([self.route_min_speed(r) for r in rr]))

    def proc_speed(self, src: int) -> float:
        """Data-transfer speed of a source processor (Eq. 5)."""
        cached = self._proc_speed.get(src)
        if cached is not None:
            return cached
        others = [d for d in range(self.n_procs) if d != src]
        return float(np.mean([self.route_speed(src, d) for d in others]))

    def all_links(self) -> List[str]:
        return list(self._all_links)

    def link_index(self) -> Dict[str, int]:
        """Stable link-name -> integer-id interning (sorted-name order)."""
        return dict(self._link_index)


def paper_topology(rates: Sequence[float] = (0.67, 1.0, 0.83),
                   ctml_mode: str = "round") -> Topology:
    """Fig. 2 of the paper.

    Star around switch s1: p1 -l1- s1, p2 -l2- s1, p3 -l4- s1, plus a direct
    p2 -l3- p3 link.  Link speeds (l1=l2=l4=1, l3=3) are the unique consistent
    assignment reproducing Table 3 route speeds and the Eq. 5 processor
    speeds (1.0, 1.5, 1.5) quoted in the text.
    """
    return Topology(
        proc_names=["p1", "p2", "p3"],
        rates=np.asarray(rates, dtype=float),
        link_speed={"l1": 1.0, "l2": 1.0, "l3": 3.0, "l4": 1.0},
        routes={
            (0, 1): [("l1", "l2"), ("l1", "l4", "l3")],
            (0, 2): [("l1", "l4"), ("l1", "l2", "l3")],
            (1, 2): [("l2", "l4"), ("l3",)],
        },
        ctml_mode=ctml_mode,
    )


def fully_switched_topology(n_procs: int, rates: Sequence[float],
                            link_speeds: Sequence[float]) -> Topology:
    """A single-switch star: every processor hangs off one switch.

    Used by the random experiments when a simple heterogeneous network is
    wanted; each pair has exactly one 2-link route through the switch.
    """
    links = {f"l{k+1}": float(s) for k, s in enumerate(link_speeds)}
    routes = {}
    for a in range(n_procs):
        for b in range(a + 1, n_procs):
            routes[(a, b)] = [(f"l{a+1}", f"l{b+1}")]
    return Topology([f"p{i+1}" for i in range(n_procs)],
                    np.asarray(rates, float), links, routes)
