"""HSV_CC baseline (Xie et al. [25]) one-shot entry point — deprecated shim.

Wraps :class:`repro.core.api.Scheduler` with the :class:`HSV_CC` policy;
bit-identical to the pre-session behaviour (priorities Eq. 8, selection
EFT * LDET_CC — HVLB_CC with alpha = 0).  Emits a ``DeprecationWarning``
once per process; new code should use the session API directly.
"""
from __future__ import annotations

from typing import Optional

from .api import HSV_CC, Scheduler
from .deprecation import warn_once
from .graph import SPG
from .scheduler import Schedule
from .topology import Topology

__all__ = ["schedule_hsv_cc"]


def schedule_hsv_cc(g: SPG, tg: Topology, engine: str = "compiled",
                    backend: Optional[str] = None) -> Schedule:
    """Deprecated: ``Scheduler(tg, policy=HSV_CC()).submit(g).schedule``."""
    warn_once("schedule_hsv_cc",
              "schedule_hsv_cc is deprecated; use repro.core.Scheduler "
              "with the HSV_CC policy")
    return Scheduler(tg, policy=HSV_CC(), engine=engine,
                     backend=backend).submit(g).schedule
