"""HSV_CC baseline (Xie et al. [25]) — the algorithm the paper improves on.

Priorities: HPRV_CC = hrank * outd (Eq. 8).  Selection: EFT * LDET_CC.
Equivalent to HVLB_CC with alpha = 0 (BP == 1).
"""
from __future__ import annotations

from .engine import CompiledInstance
from .graph import SPG
from .ranks import hprv_a, hrank, priority_queue, rank_matrix
from .scheduler import Schedule, list_schedule
from .topology import Topology


def schedule_hsv_cc(g: SPG, tg: Topology,
                    engine: str = "compiled") -> Schedule:
    rank = rank_matrix(g, tg)
    h = rank.mean(axis=1)
    queue = priority_queue(hprv_a(g, tg, rank), h)
    if engine == "reference":
        return list_schedule(g, tg, queue, rank, alpha=0.0)
    if engine != "compiled":
        raise ValueError(f"unknown engine {engine!r}")
    return CompiledInstance(g, tg, rank=rank).schedule(queue, alpha=0.0)
