"""Imprecise-computation extension HVLB_CC_IC (Section 4.4).

A task subject to varying input arrival rates is split into a *mandatory*
part and an *optional* part (Eq. 19).  The optional part may run inside a
*schedule hole*: processor idle time after the task that can be consumed
without delaying (a) the next task on the same processor, (b) any
same-processor successor, or (c) the departure of any outgoing message,
where messages may themselves be re-timed into link idle slots as long as no
successor's start is pushed back (Eqs. 20-21; the paper's LST'' re-timing).

Precision of a task under arrival rate lambda (Experiment 5):
  requested optional time  op_req = (lambda - 1) * mp
  executed optional time   op_run = min(op_req, hole)   (0 without IC)
  precision = (mp + op_run) / (mp + op_req)
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .scheduler import Schedule


def schedule_holes(s: Schedule,
                   include_unbounded: bool = False) -> Dict[int, float]:
    """Maximum extension time available after each task (Eqs. 20-21).

    A task with *nothing* after it — no later task on its processor, no
    successor anywhere — has an unbounded hole.  By default such tasks
    are omitted (matching tasks with no usable hole); with
    ``include_unbounded=True`` they are reported as ``float("inf")``,
    which is what the imprecise-computation consumers want (``min(op_req,
    inf) == op_req``: the optional part always fits).
    """
    g, tg = s.graph, s.topology
    holes: Dict[int, float] = {}
    link_ivs = s.link_intervals()

    for p_task in range(g.n):
        p = int(s.proc[p_task])
        aft = float(s.finish[p_task])
        bounds: List[float] = []

        # (a) next task on the same processor
        on_p = s.tasks_on(p)
        idx = on_p.index(p_task)
        if idx + 1 < len(on_p):
            bounds.append(float(s.start[on_p[idx + 1]]))

        for n_s in g.succ[p_task]:
            if int(s.proc[n_s]) == p:
                # (b) same-processor successor: condition 1 (Eq. 20)
                bounds.append(float(s.start[n_s]))
            else:
                # (c) different processor: condition 2 (Eq. 21) — the
                # message may be delayed to LST'' = LST + slack, where the
                # slack is limited by the successor's start and by the next
                # message queued behind it on every link of its route.
                m = s.messages[(p_task, n_s)]
                slack = float(s.start[n_s]) - m.lft
                for (l, st, fi) in m.intervals:
                    nxt = [iv for iv in link_ivs[l] if iv[0] >= fi - 1e-9
                           and iv[2] != m.edge]
                    if nxt:
                        slack = min(slack, nxt[0][0] - fi)
                bounds.append(m.lst + max(0.0, slack))

        if not bounds:
            # exit task with nothing after it: unbounded hole
            if include_unbounded:
                holes[p_task] = float("inf")
            continue
        hole = min(bounds) - aft
        if hole > 1e-9:
            holes[p_task] = hole
    return holes


def precision(mp: float, hole: float, lam: float, *, ic: bool) -> float:
    """Data precision of one imprecise task at arrival rate ``lam``."""
    op_req = (lam - 1.0) * mp
    if op_req <= 0:
        return 1.0
    op_run = min(op_req, hole) if ic else 0.0
    return (mp + op_run) / (mp + op_req)


def precision_curve(s: Schedule, tasks: List[int], lams: np.ndarray,
                    *, ic: bool) -> Dict[int, np.ndarray]:
    """Experiment-5 curves for the given imprecise-model tasks."""
    g, tg = s.graph, s.topology
    holes = schedule_holes(s)
    out: Dict[int, np.ndarray] = {}
    for t in tasks:
        mp = g.comp(t, int(s.proc[t]), tg.rates)
        hole = holes.get(t, 0.0)
        out[t] = np.array([precision(mp, hole, l, ic=ic) for l in lams])
    return out
