"""Fault model for fault-tolerant scheduling (DESIGN.md §6).

Automotive DSMSs lose resources mid-run: an ECU stalls
(:class:`ProcessorDown`), a CAN/FlexRay segment degrades or drops
(:class:`LinkDegraded` / :class:`LinkDown`), a task's computation time
spikes under load (:class:`ComputeSpike`).  This module is the *model*
only — declarative fault records, a normalized :class:`FaultSpec`, and
pure masked views of a :class:`~.topology.Topology` / :class:`~.graph.SPG`.
Injection and replanning live in :meth:`api.Scheduler.mark_failed` /
:meth:`api.Scheduler.degrade`; enforcement lives in
:class:`~.engine.CompiledInstance` (masked comp columns / effective link
speeds) and :mod:`.validate` (the independent oracle).

Masking is *finite*: a down processor's computation column is set to
:data:`DOWN_COMP` and a down link's speed to :data:`DOWN_SPEED` rather
than ``inf`` / ``0``.  Every backend then runs the exact same IEEE
arithmetic as the healthy path — no ``inf - inf``/``inf * 0`` NaNs, no
divide-by-zero, and the bit-exactness contract between the scalar,
vector, and pallas evaluators is untouched.  A candidate forced through
a masked resource lands at an EFT beyond :data:`INFEASIBLE_EFT` and can
never beat a feasible candidate; if the *winner* lands there, no
feasible placement exists and the engine raises
:class:`InfeasibleScheduleError`.

The priority heuristics (rank / LDET / HPRV queues) intentionally keep
the *healthy* topology: priorities are estimates, not feasibility, and
freezing them is what makes the fault-invalidation rule exact — the
decision-trace prefix untouched by the failed resource is provably
unchanged and is re-committed rather than re-simulated (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple, Union

import numpy as np

from .graph import SPG
from .topology import Topology

_INF = float("inf")

# Finite masking sentinels (see module docstring).  DOWN_COMP is exactly
# representable in float32 as well, so the pallas f32 path carries it
# losslessly; INFEASIBLE_EFT leaves three orders of magnitude of headroom
# above any realistic schedule horizon before a masked candidate's EFT.
DOWN_COMP = 1e18        # comp(task, down proc)
DOWN_SPEED = 1e-18      # effective speed of a down link
INFEASIBLE_EFT = 1e15   # winner EFT at/above this => no feasible placement


class InfeasibleScheduleError(RuntimeError):
    """No feasible placement remains for a task under the active faults.

    Raised by the engine the moment a decision's *winning* candidate is
    only reachable through a masked (failed) resource — instead of
    silently scheduling onto a dead processor or link.  ``task`` is the
    graph node that could not be placed.
    """

    def __init__(self, task: int, eft: float, faults: "FaultSpec") -> None:
        self.task = task
        self.eft = eft
        self.faults = faults
        super().__init__(
            f"no feasible placement for task {task} under active faults "
            f"{faults.describe()} (winning EFT {eft:.3g} exceeds the "
            f"feasibility horizon)")


class WaveTimeoutError(RuntimeError):
    """A candidate-evaluation wave exceeded the engine watchdog budget.

    Raised by :meth:`~.engine.CompiledInstance._run` when a single
    ``evaluate_batch`` call takes longer than the configured
    ``wave_timeout`` — the hung-device-backend signal the session-level
    fallback chain demotes on (``api.Scheduler``).
    """

    def __init__(self, wave: int, elapsed: float, timeout: float) -> None:
        self.wave = wave
        self.elapsed = elapsed
        self.timeout = timeout
        super().__init__(
            f"candidate-evaluation wave {wave} took {elapsed:.3f}s "
            f"(watchdog budget {timeout:.3f}s)")


# ----------------------------------------------------------------------
# Declarative fault records
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ProcessorDown:
    """Processor ``proc`` (index into ``Topology.proc_names``) is dead."""

    proc: int


@dataclasses.dataclass(frozen=True)
class LinkDegraded:
    """Link ``link`` runs at ``1/factor`` of its nominal speed
    (``factor >= 1``: CTML of every message on it scales by factor)."""

    link: str
    factor: float


@dataclasses.dataclass(frozen=True)
class LinkDown:
    """Link ``link`` is unusable (equivalent to an infinite factor)."""

    link: str


@dataclasses.dataclass(frozen=True)
class ComputeSpike:
    """Task ``task``'s computational volume scales by ``factor``.

    Flows through the same arrival-rate-drift machinery as
    :meth:`api.Scheduler.update` (``task_rates``); kept in the taxonomy
    so fault scripts can be declared uniformly.
    """

    task: int
    factor: float


Fault = Union[ProcessorDown, LinkDegraded, LinkDown, ComputeSpike]


# ----------------------------------------------------------------------
# Normalized fault state
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Normalized, hashable snapshot of the active resource faults.

    ``down_procs`` is a sorted tuple of processor indices;
    ``link_factors`` a sorted tuple of ``(link_name, factor)`` pairs
    where ``factor == inf`` means the link is down.  (:class:`ComputeSpike`
    is *not* part of the spec — computation drift rescales the graph and
    rides the existing ``update(task_rates=...)`` path.)
    """

    down_procs: Tuple[int, ...] = ()
    link_factors: Tuple[Tuple[str, float], ...] = ()

    # ------------------------------------------------------------- build
    @classmethod
    def from_faults(cls, faults: Iterable[Fault],
                    tg: Topology) -> "FaultSpec":
        """Validate resource ids against ``tg`` and normalize.

        Later records override earlier ones for the same link;
        :class:`ComputeSpike` records are rejected here (they are graph
        drift, not resource state — apply them via
        ``Scheduler.degrade(task=...)`` / ``update(task_rates=...)``).
        """
        down = set()
        factors: Dict[str, float] = {}
        for f in faults:
            if isinstance(f, ProcessorDown):
                if not 0 <= f.proc < tg.n_procs:
                    raise ValueError(
                        f"ProcessorDown: processor index {f.proc} out of "
                        f"range for a {tg.n_procs}-processor topology")
                down.add(int(f.proc))
            elif isinstance(f, LinkDegraded):
                _check_link(f.link, tg)
                fac = float(f.factor)
                if not np.isfinite(fac) or fac <= 0.0:
                    raise ValueError(
                        f"LinkDegraded: factor must be a finite positive "
                        f"number, got {f.factor!r} (use LinkDown for an "
                        f"unusable link)")
                factors[f.link] = fac
            elif isinstance(f, LinkDown):
                _check_link(f.link, tg)
                factors[f.link] = _INF
            elif isinstance(f, ComputeSpike):
                raise ValueError(
                    "ComputeSpike is computation drift, not resource "
                    "state: apply it via Scheduler.degrade(task=..., "
                    "factor=...) or update(task_rates=...)")
            else:
                raise TypeError(f"not a fault record: {f!r}")
        if len(down) >= tg.n_procs:
            raise ValueError("every processor marked down — nothing left "
                             "to schedule on")
        return cls(tuple(sorted(down)),
                   tuple(sorted(factors.items())))

    # ----------------------------------------------------------- queries
    @property
    def is_empty(self) -> bool:
        return not self.down_procs and not self.link_factors

    @property
    def down_links(self) -> Tuple[str, ...]:
        return tuple(l for l, f in self.link_factors if f == _INF)

    def link_factor(self, link: str) -> float:
        for l, f in self.link_factors:
            if l == link:
                return f
        return 1.0

    def effective_speed(self, link: str, raw_speed: float) -> float:
        """Masked speed of one link (:data:`DOWN_SPEED` when down)."""
        f = self.link_factor(link)
        if f == _INF:
            return DOWN_SPEED
        return raw_speed / f

    def describe(self) -> str:
        parts = [f"proc {p} down" for p in self.down_procs]
        for l, f in self.link_factors:
            parts.append(f"link {l} down" if f == _INF
                         else f"link {l} degraded x{f:g}")
        return "[" + ", ".join(parts) + "]" if parts else "[none]"

    # ----------------------------------------------------------- algebra
    def with_fault(self, fault: Fault, tg: Topology) -> "FaultSpec":
        """Spec with one more fault applied (link records override)."""
        merged = list(self._records()) + [fault]
        return FaultSpec.from_faults(merged, tg)

    def without(self, *, proc: Optional[int] = None,
                link: Optional[str] = None) -> "FaultSpec":
        """Spec with one resource restored (no-op if it was healthy)."""
        down = tuple(p for p in self.down_procs if p != proc)
        factors = tuple((l, f) for l, f in self.link_factors if l != link)
        return FaultSpec(down, factors)

    def _records(self) -> Tuple[Fault, ...]:
        recs: list = [ProcessorDown(p) for p in self.down_procs]
        for l, f in self.link_factors:
            recs.append(LinkDown(l) if f == _INF else LinkDegraded(l, f))
        return tuple(recs)


def _check_link(link: str, tg: Topology) -> None:
    if link not in tg.link_speed:
        raise ValueError(f"unknown link {link!r} (topology links: "
                         f"{tg.all_links()})")


# ----------------------------------------------------------------------
# Pure masked views
# ----------------------------------------------------------------------
def apply_to_topology(tg: Topology, spec: FaultSpec) -> Topology:
    """A new :class:`Topology` whose link speeds carry the fault masking.

    Pure view: ``tg`` is untouched.  Down links get speed 0.0 (their
    CTML is ``inf`` — :meth:`Topology.ctml` guards the division), so the
    view is honest for inspection and the validator; the *engine* masks
    at the :class:`~.engine.CompiledInstance` level instead (finite
    :data:`DOWN_SPEED`, see module docstring) and never consumes this.
    Down processors cannot be dropped from a topology without renaming
    every index, so they are not represented here — processor masking is
    a property of the spec, not the view.
    """
    speeds = {l: (0.0 if spec.link_factor(l) == _INF
                  else s / spec.link_factor(l))
              for l, s in tg.link_speed.items()}
    return Topology(list(tg.proc_names), tg.rates.copy(), speeds,
                    {pair: list(rr) for pair, rr in tg.routes.items()},
                    ctml_mode=tg.ctml_mode)


def apply_to_graph(g: SPG, spikes: Iterable[ComputeSpike]) -> SPG:
    """A new :class:`SPG` with :class:`ComputeSpike` volume scaling
    applied (pure view; structure/names preserved)."""
    w = g.weights.copy()
    cm = None if g.comp_matrix is None else np.array(g.comp_matrix,
                                                    dtype=float)
    for s in spikes:
        if not 0 <= s.task < g.n:
            raise ValueError(f"ComputeSpike: task {s.task} out of range "
                             f"for a {g.n}-task graph")
        fac = float(s.factor)
        if not np.isfinite(fac) or fac <= 0.0:
            raise ValueError(f"ComputeSpike: factor must be a finite "
                             f"positive number, got {s.factor!r}")
        w[s.task] *= fac
        if cm is not None:
            cm[s.task] *= fac
    return SPG(n=g.n, edges=list(g.edges), weights=w, tpl=dict(g.tpl),
               tpl_proportional_ccr=g.tpl_proportional_ccr,
               comp_matrix=cm, name=g.name)
