"""Stream Processing Graph (SPG) — Definition 2.2 of the paper.

An SPG is a DAG ``G = <V(G), E(G)>`` whose nodes are stream operators (tasks)
with a computational volume ``w_i`` and whose edges carry a communication
volume ``tpl(e_ij)`` (a tuple batch).  The paper's worked example (Fig. 3,
Table 1) ships as :func:`paper_spg`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Edge = Tuple[int, int]


@dataclasses.dataclass
class SPG:
    """Directed acyclic stream-processing graph.

    Nodes are ``0..n-1`` (the paper's ``n1`` is node ``0``).  ``weights[i]``
    is the computational volume ``w_i`` (Definition 2.1).  ``tpl[(i, j)]`` is
    the communication volume of edge ``e_{i,j}``; when
    ``tpl_proportional_ccr`` is set instead, the worked-example convention of
    the paper is used: ``tpl(e_ij | p_src) = CCR * comp(n_i, p_src)`` (this is
    the only convention that reproduces Table 2 of the paper exactly).
    """

    n: int
    edges: List[Edge]
    weights: np.ndarray
    tpl: Dict[Edge, float] = dataclasses.field(default_factory=dict)
    tpl_proportional_ccr: Optional[float] = None
    # Optional explicit per-processor computation-time matrix (n x p).  When
    # given it overrides ``weights / rate`` (the paper's tables are rounded,
    # so exact reproduction needs the table itself).
    comp_matrix: Optional[np.ndarray] = None
    name: str = "spg"

    def __post_init__(self) -> None:
        self.weights = np.asarray(self.weights, dtype=float)
        if self.weights.shape != (self.n,):
            raise ValueError(f"weights must have shape ({self.n},)")
        self.succ: List[List[int]] = [[] for _ in range(self.n)]
        self.pred: List[List[int]] = [[] for _ in range(self.n)]
        seen = set()
        for (i, j) in self.edges:
            if not (0 <= i < self.n and 0 <= j < self.n):
                raise ValueError(f"edge ({i},{j}) out of range")
            if (i, j) in seen:
                raise ValueError(f"duplicate edge ({i},{j})")
            seen.add((i, j))
            self.succ[i].append(j)
            self.pred[j].append(i)
        self._topo = self._toposort()
        self.depth = self._depths()
        self._comp_cache: Dict[bytes, np.ndarray] = {}

    # ------------------------------------------------------------------
    def _toposort(self) -> List[int]:
        indeg = [len(self.pred[i]) for i in range(self.n)]
        stack = [i for i in range(self.n) if indeg[i] == 0]
        order: List[int] = []
        while stack:
            u = stack.pop()
            order.append(u)
            for v in self.succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != self.n:
            raise ValueError("graph has a cycle")
        return order

    def _depths(self) -> np.ndarray:
        """Paper depth: 1 + length of the longest entry->node path."""
        depth = np.ones(self.n, dtype=int)
        for u in self._topo:
            for v in self.succ[u]:
                depth[v] = max(depth[v], depth[u] + 1)
        return depth

    # ------------------------------------------------------------------
    @property
    def topo_order(self) -> List[int]:
        return list(self._topo)

    def outd(self, i: int) -> int:
        return len(self.succ[i])

    def ind(self, i: int) -> int:
        return len(self.pred[i])

    @property
    def entries(self) -> List[int]:
        return [i for i in range(self.n) if not self.pred[i]]

    @property
    def exits(self) -> List[int]:
        return [i for i in range(self.n) if not self.succ[i]]

    @property
    def max_outd(self) -> int:
        return max(len(s) for s in self.succ)

    # ------------------------------------------------------------------
    def comp(self, i: int, pu: int, rates: Sequence[float]) -> float:
        """Computation time of task ``i`` on processor ``pu`` (Eq. 1)."""
        if self.comp_matrix is not None:
            return float(self.comp_matrix[i, pu])
        return float(self.weights[i]) / float(rates[pu])

    def comp_matrix_for(self, rates: Sequence[float]) -> np.ndarray:
        """Cached ``(n, P)`` computation-time matrix for a rate vector.

        Entry ``[i, p]`` is bit-identical to ``comp(i, p, rates)`` — the
        compiled engine and the vectorized rank computation index this array
        instead of calling :meth:`comp` per scalar.
        """
        rates_arr = np.asarray(rates, dtype=float)
        # with an explicit matrix the rates are ignored (Eq. 1 override)
        key = b"" if self.comp_matrix is not None else rates_arr.tobytes()
        cached = self._comp_cache.get(key)
        if cached is None:
            if len(self._comp_cache) >= 8:
                # replan loops feed continuously drifting measured rates;
                # rebuilding is cheap, so cap the cache instead of leaking
                self._comp_cache.clear()
            if self.comp_matrix is not None:
                cached = np.asarray(self.comp_matrix, dtype=float).copy()
            else:
                cached = self.weights[:, None] / rates_arr[None, :]
            cached.setflags(write=False)
            self._comp_cache[key] = cached
        return cached

    def comm_volume(self, i: int, j: int, comp_src: float) -> float:
        """Communication volume ``tpl(e_ij)``.

        ``comp_src`` is ``comp(n_i, p_src)`` — used only by the paper's
        worked-example convention (tpl proportional to the source task's
        computation time, scaled by CCR).
        """
        if self.tpl_proportional_ccr is not None:
            return self.tpl_proportional_ccr * comp_src
        return float(self.tpl[(i, j)])

    def default_period(self, rates: Sequence[float], n_procs: int) -> float:
        """Sum of per-task minimum computation times — the Definition-4.1
        application-period proxy used when no explicit period is given.

        Single source of truth for the reference scheduler, the compiled
        engine, and the session API: the engine/reference bit-identity
        guarantee for ``period=None`` depends on all of them summing the
        same floats in the same order.
        """
        comp = self.comp_matrix_for(rates)[:, :n_procs]
        return float(sum(min(row) for row in comp.tolist()))

    def critical_path_min_comp(self, rates: Sequence[float],
                               n_procs: int) -> float:
        """Denominator of SLR (Eq. 22): the min-computation critical path."""
        best = np.zeros(self.n)
        for u in reversed(self._topo):
            c = min(self.comp(u, p, rates) for p in range(n_procs))
            tail = max((best[v] for v in self.succ[u]), default=0.0)
            best[u] = c + tail
        return float(max(best[e] for e in self.entries))


# ----------------------------------------------------------------------
# The paper's worked example (Fig. 3 / Tables 1-2).
# Edge set reverse-engineered from the paper and verified against every rank
# value of Table 2 (see tests/test_paper_example.py):
#   pred(n5) = {n1,n2,n3}; succ(n5) = {n7,n8}; e(3,6); e(6,9); e(8,9);
#   e(7,10); succ(n1) = succ(n2) = {n4,n5}; succ(n4) = {n7,n8}.
PAPER_EDGES: List[Edge] = [
    (0, 3), (0, 4),          # n1 -> n4, n5
    (1, 3), (1, 4),          # n2 -> n4, n5
    (2, 4), (2, 5),          # n3 -> n5, n6
    (3, 6), (3, 7),          # n4 -> n7, n8
    (4, 6), (4, 7),          # n5 -> n7, n8
    (5, 8),                  # n6 -> n9
    (6, 9),                  # n7 -> n10
    (7, 8),                  # n8 -> n9
]

# Table 1 computation-time matrix (tasks x processors p1,p2,p3).
PAPER_COMP = np.array([
    [18, 12, 14],
    [12, 8, 10],
    [12, 8, 10],
    [21, 14, 17],
    [9, 6, 7],
    [15, 10, 12],
    [26, 17, 20],
    [14, 9, 11],
    [20, 13, 16],
    [15, 10, 12],
], dtype=float)

# Table 4 computation-time matrix (Experiment 5).
PAPER_COMP_EXP5 = np.array([
    [26, 17, 20],
    [26, 17, 20],
    [14, 9, 11],
    [12, 8, 10],
    [17, 11, 13],
    [30, 20, 24],
    [9, 6, 7],
    [27, 18, 22],
    [27, 18, 22],
    [30, 20, 24],
], dtype=float)


def paper_spg(ccr: float = 1.0, comp: Optional[np.ndarray] = None) -> SPG:
    """Fig. 3 SPG with Table 1 times (or a supplied matrix, e.g. Table 4)."""
    comp = PAPER_COMP if comp is None else comp
    # weights w_i such that comp on p2 (rate 1.0) equals the table.
    return SPG(
        n=10,
        edges=list(PAPER_EDGES),
        weights=comp[:, 1].copy(),
        tpl_proportional_ccr=ccr,
        comp_matrix=comp.copy(),
        name="paper_fig3",
    )
