"""Vectorized candidate-evaluation backend: (P,)-batch NumPy array ops.

Evaluates all ``P`` placement candidates of one dequeued task at once.
The per-candidate tentative link state lives in one flat ``(P*L + 2,)``
buffer — lane ``p`` owns slots ``[p*L, (p+1)*L)``, a *sink* slot absorbs
writes that the scalar path would not perform (same-processor
predecessors, hop padding), and a read-only ``-inf`` slot feeds reads
that must not constrain a start time.  Rollback is free: lanes never
alias, and committing the winner is the shared scalar
:meth:`~.base.CandidateEvaluator.apply`.

The message-routing recurrences (Eqs. 13-14) are running maxima, and
``max`` is associative/commutative and *exact* in IEEE-754, so

    LST_h = max(aft_i, avail_0, ..., avail_h)
    LFT_h = max(x_0, ..., x_h),  x_h = LST_h + CTML_h

reassociate freely without changing a single bit; each hop is one
``(P,)`` row op.  Committing a route needs no read-back either:
``LFT_h >= avail_h`` (CTML >= 0), so the scalar path's ``if f > old``
write is a plain scatter.  Every inexact operation (adds, multiplies,
divides, comparisons) is performed elementwise in the reference's
operand order, which is what keeps this backend bit-identical to
:class:`~.scalar.ScalarBackend` (``tests/test_backend_equivalence.py``
holds it to exact float equality on the full corpus).

Per-lane BP terms are cached incrementally: ``loads[p]`` changes only
when a decision commits, so ``apply`` refreshes ``loads[p]/period`` and
``1 + (loads[p]/period)*alpha`` for the winner lane alone — the same
scalars the reference recomputes per candidate.

Dispatch-overhead notes (this is a small-array regime — P*H is tens of
elements, so per-call overhead dominates): allocating ufunc forms beat
``out=`` kwargs, ``.take``/fancy gathers beat ``np.take(out=)``, winner
selection runs on ``.tolist()`` floats (exact — tolist round-trips the
IEEE value), and single-predecessor tasks skip the lane-buffer
broadcast entirely by gathering straight from the committed link state.

Routes are padded per source processor to hop-major tensors (the shared
:mod:`.layout` precompute, built once per ``(instance, src)`` and reused
by every edge and every array backend): hop padding reads ``-inf`` and
adds ``-inf`` CTML (both maxima become no-ops), route padding is masked
to ``+inf`` arrival so it never wins the (LFT, hops, index) route
selection.  The ``src`` lane gets a fake zero-CTML route whose final
LFT is exactly ``aft_i`` — the scalar path's same-processor arrival
contribution — so no post-hoc masking is needed.  The only per-edge
work left on a cold submit is one vectorized Eq. 15 CTML fill
(:func:`.layout.edge_ct`), which is what keeps a cold pass within
~1.2x of a warm one (``exp7.cold_submit_us``).

Requires every route to visit each link at most once (true for every
in-tree topology); otherwise :class:`BackendCompatError` is raised and
``backend="auto"`` falls back to scalar (``resolve_backend_name``
rejects an explicit ``backend="vector"`` up front).
"""
from __future__ import annotations

import numpy as np

from .base import BackendCompatError, CandidateEvaluator, Decision
from .layout import ensure_ct_table, src_layout

_INF = float("inf")
_NEG_INF = float("-inf")


class VectorBackend(CandidateEvaluator):
    """(P,)-batch candidate evaluation on NumPy arrays."""

    name = "vector"

    def __init__(self, inst) -> None:
        super().__init__(inst)
        for pair, rr in inst._routes.items():
            for (lids, _spds, _robj) in rr:
                if len(set(lids)) != len(lids):
                    raise BackendCompatError(
                        f"route {pair} visits a link twice; the vector "
                        "backend's batched scatter needs link-disjoint "
                        "routes — use backend='scalar'")
        P, L = inst.P, inst._n_links
        self._L = L
        self._sink = P * L
        self._neg = P * L + 1
        self._tent = np.empty(P * L + 2, dtype=np.float64)
        self._tent2d = self._tent[:P * L].reshape(P, L)
        self._tent[self._sink] = 0.0         # write-only garbage slot
        self._tent[self._neg] = _NEG_INF     # read-only, never written

    def _alloc(self) -> None:
        inst = self.inst
        P, L = inst.P, self._L
        # committed link state, with a trailing read-only -inf slot so
        # single-pred gathers can use it directly (base_idx space)
        self.link_free = np.zeros(L + 1, dtype=np.float64)
        self.link_free[L] = _NEG_INF
        self._lf = self.link_free[:L]
        self.proc_free = np.zeros(P, dtype=np.float64)
        self.loads = np.zeros(P, dtype=np.float64)
        # incrementally maintained Def.-4.1 terms (see apply)
        self._lop = np.zeros(P, dtype=np.float64)
        self._bp = np.ones(P, dtype=np.float64)

    def apply(self, j: int, p: int, est: float, eft: float,
              msgs: list) -> None:
        super().apply(j, p, est, eft, msgs)
        # only the winner lane's load changed; refresh its BP terms with
        # the exact scalar expressions the reference uses per candidate
        lop = self.loads[p] / self.period
        self._lop[p] = lop
        self._bp[p] = 1.0 + lop * self.alpha

    # ------------------------------------------------------------------
    def evaluate(self, j: int) -> Decision:
        inst = self.inst
        P = inst.P
        aft = self.aft
        proc_of = self.proc_of
        tent = self._tent
        layouts = inst._src_layouts
        edge_index = inst._edge_index
        maximum = np.maximum

        preds = inst._preds[j]
        n_preds = len(preds)
        if n_preds > 1:
            preds = sorted(preds, key=lambda i: (aft[i], i))
            np.copyto(self._tent2d, self._lf)    # every lane: base state
        tent_ready = n_preds > 1
        last = n_preds - 1
        finals = []
        walks = []                               # winner-lane msgs data
        for k in range(n_preds):
            i = preds[k]
            src = proc_of[i]
            aft_i = aft[i]
            # shared per-src layout + precompiled all-edge CTML table:
            # nothing is built per (edge, src), so a cold pass costs the
            # same as a warm one (modulo P one-time layout builds).
            # This inlines layout.src_layout/edge_ct's cache-hit paths —
            # misses delegate to the helpers, hits stay a dict lookup
            # (this loop runs once per predecessor per decision)
            lay = layouts.get(src)
            if lay is None:
                lay = src_layout(inst, src)
            ct = lay.ct_table
            if ct is None:
                ct = ensure_ct_table(inst, lay)
            ct = ct[edge_index[(i, j)]]
            if lay.R == 1:
                if tent_ready:
                    av = tent.take(lay.av_idx)
                else:                            # single pred: read the
                    av = self.link_free.take(lay.base_flat)  # base directly
                commit = k < last                # last pred: no readers
                lst_rows = []
                lft_rows = []
                lst = lft = None
                for h in range(lay.H):
                    avh = av[h * P:(h + 1) * P]
                    lst = maximum(avh, aft_i) if h == 0 \
                        else maximum(avh, lst)   # Eq. 13, reassociated
                    x = lst + ct[h]              # hop-major table row
                    lft = x if h == 0 else maximum(lft, x)   # Eq. 14
                    if commit:
                        # LFT_h >= avail_h always: plain scatter commit
                        tent[lay.w_rows[h]] = lft
                    lst_rows.append(lst)
                    lft_rows.append(lft)
                finals.append(lft)
                walks.append((i, src, lay, lst_rows, lft_rows, None))
                continue
            # ---- multi-route general path ----
            if not tent_ready:
                np.copyto(self._tent2d, self._lf)
                tent_ready = True
            avail = tent[lay.read_idx]           # (P, R, H) gather
            lst3 = np.maximum.accumulate(avail, axis=2)
            lst3 = maximum(lst3, aft_i)
            lft3 = np.maximum.accumulate(lst3 + ct, axis=2)
            final = lft3[:, :, -1]               # (P, R) route arrivals
            if lay.has_invalid:
                final = np.where(lay.invalid, _INF, final)
            # lexicographic (LFT, hops, route-index) min per lane
            nhops = lay.nhops
            best_f = final[:, 0].copy()
            best_nh = nhops[:, 0].copy()
            best_r = np.zeros(P, dtype=np.intp)
            for r in range(1, lay.R):
                f = final[:, r]
                better = (f < best_f) | ((f == best_f) &
                                         (nhops[:, r] < best_nh))
                np.copyto(best_f, f, where=better)
                np.copyto(best_nh, nhops[:, r], where=better)
                best_r[better] = r
            sel = best_r[:, None, None]
            lft_sel = np.take_along_axis(lft3, sel, axis=1)[:, 0, :]
            wi = np.take_along_axis(lay.write_idx, sel,
                                    axis=1)[:, 0, :].ravel()
            tent[wi] = lft_sel.ravel()
            finals.append(best_f)
            walks.append((i, src, lay, lst3, lft3, best_r))

        # ---- batched Eqs. 10-12 + Defs. 4.1-4.2 over all P lanes ----
        if not finals:
            est = self.proc_free                 # arrival == 0 <= proc_free
        elif n_preds == 1:
            est = maximum(self.proc_free, finals[0])
        else:
            acc = maximum(finals[0], finals[1])
            for f in finals[2:]:
                acc = maximum(acc, f)
            est = maximum(acc, self.proc_free)   # Eqs. 10-11, reassociated
        eft = est + inst.comp[j]                 # Eq. 12
        exit_j = inst._is_exit[j]
        track = self.want_bound and not exit_j
        if exit_j:
            A = None
            value = eft                          # Def. 4.2
        else:
            A = eft * inst.ldet[j]
            value = A * self._bp                 # Def. 4.1 (cached BP)

        # strict lexicographic (value, eft, proc) argmin, first-index
        # ties — on exact tolist floats, matching the scalar loop
        vl = value.tolist()
        el = eft.tolist()
        p = 0
        bv = vl[0]
        be = el[0]
        for q in range(1, P):
            v = vl[q]
            if v < bv or (v == bv and el[q] < be):
                p, bv, be = q, v, el[q]

        msgs = []
        for (i, src, lay, lst_w, lft_w, best_r) in walks:
            if src == p:
                continue
            if best_r is None:                   # hop-major rows
                lids, robj = lay.route_meta[p][0]
                msgs.append((i, robj,
                             [(lids[h], float(lst_w[h][p]),
                               float(lft_w[h][p]))
                              for h in range(len(lids))]))
            else:
                r = int(best_r[p])
                lids, robj = lay.route_meta[p][r]
                msgs.append((i, robj,
                             [(lids[h], float(lst_w[p, r, h]),
                               float(lft_w[p, r, h]))
                              for h in range(len(lids))]))

        if track:
            B = A * self._lop
            contrib = self._crossing_vec(p, A, B)
            ca, cb = tuple(A.tolist()), tuple(B.tolist())
        else:
            ca = cb = None
            contrib = _INF
        return p, float(est[p]), be, msgs, ca, cb, contrib

    # ------------------------------------------------------------------
    def _crossing_vec(self, p: int, A: np.ndarray, B: np.ndarray) -> float:
        """Vectorized :meth:`~.base.CandidateEvaluator.crossing`: same
        divisions on the same operands, ``min`` is order-free, so the
        returned float is identical to the scalar rival loop."""
        d_b = B[p] - B
        d_a = A - A[p]
        scale = np.abs(A) + abs(A[p])
        scale += 1.0
        thr = 1e-15 * scale
        mask1 = d_b > thr
        contrib = _INF
        if mask1.any():
            a_star = d_a / np.where(mask1, d_b, 1.0)
            contrib = float(np.where(mask1, a_star, _INF).min())
        mask2 = (np.abs(d_b) <= thr) & (np.abs(d_a) <= 1e-12 * scale)
        mask2[p] = False                 # the scalar loop skips the winner
        if mask2.any() and self.alpha < contrib:
            contrib = self.alpha
        return contrib
