"""JAX/Pallas candidate-evaluation backend: one device kernel per *batch*.

The engine's decision layer hands this backend whole **waves** of
independent, same-rank-level tasks (``evaluate_batch``); a single
:func:`pallas_call` evaluates every decision of the wave over all ``P``
placement candidates, commits each winner to device-resident link and
processor state *inside the kernel*, and returns the per-decision
winner/EFT/coefficient arrays in one host transfer.  Host round-trips
per schedule therefore drop from O(decisions) (the PR-4 per-decision
kernel) to O(levels) — the HVLB_CC (B) priority order is approximately
level-sorted, so the queue decomposes into roughly one wave per rank
level.

Per batch the kernel unrolls the decisions in queue order; decision
``b``:

  1. broadcasts the carried ``(L,)`` link state into a ``(P, L)`` *lane
     buffer* (lane ``p`` = candidate processor ``p``'s tentative link
     state),
  2. walks the task's predecessors in the scalar reference's
     ``(aft, id)`` order; per predecessor it runs the Eq. 13-14
     recurrences as **masked row ops** — ``avail_h`` is a masked max
     over the link axis, ``LST``/``LFT`` are running ``(P,)`` maxima —
     selects the best route per lane by the lexicographic
     ``(LFT, hops, index)`` rule, and commits the winning route's hop
     LFTs back into the lane buffer (masked writes),
  3. batches Eqs. 10-12 and Defs. 4.1-4.2 over all lanes, picks the
     strict lexicographic ``(value, EFT, proc)`` argmin winner, and
  4. **commits in-kernel**: the winner lane's column of the lane buffer
     *is* the post-decision link state (masked overwrites reproduce the
     scalar max-commits exactly), and ``proc_free``/``loads``/
     ``loads/period``/``BP`` update through a winner one-hot — so
     decision ``b+1`` evaluates against exactly the state the scalar
     walk would have left.

Link/processor state lives on device across the whole schedule: the
kernel returns the updated state arrays, which stay on device as the
carry for the next wave (never fetched).  The host keeps float64
mirrors in sync through the *shared* scalar
:meth:`~.base.CandidateEvaluator.apply` commits on the returned
decision floats — that is what keeps decision traces backend-portable
(pallas <-> scalar resume) — and re-uploads the mirrors wholesale
(one transfer, ``_state_dirty``) after a trace replay touched them.

Precision has two modes, selected per process:

  * **float64 interpreter** (the default off-TPU, CI): every operation
    is the same IEEE-754 double arithmetic as the scalar reference — in
    practice bit-identical, asserted decision-identical
    (``tests/test_backend_equivalence.py``).
  * **float32 tiled** (the default on TPU, where f64 does not exist;
    forced anywhere via ``REPRO_PALLAS_DTYPE=float32`` for testing):
    shapes are tile-padded (``layout.pad_dim`` — P to a sublane
    multiple, L to a lane multiple) so the kernel Mosaic-compiles, and
    the contract relaxes to the documented **near-tie policy**: the
    schedule is decision-identical to scalar except where two
    candidates' selection values differ by less than
    :data:`F32_NEAR_TIE_RTOL` (relative), in which case the winner is
    the f32-lexicographic ``(value, EFT, proc)`` argmin — pinned
    deterministic for fixed inputs (first index on exact f32 ties).
    ``REPRO_PALLAS_TILE=1/0`` forces tile padding independently (the
    padding is arithmetic-neutral, so it can be exercised under the
    interpreter).

``REPRO_PALLAS_INTERPRET=1/0`` forces interpreter/compiled dispatch
(default: compiled only on TPU).  Compiled kernels are cached per
padded static shape in a bounded LRU (:data:`_RUN_CACHE`, capacity
:data:`_RUN_CACHE_MAX`); eviction only drops a compiled artifact — a
rebuilt kernel is deterministic, so results never change.  Batch sizes
are bucketed to powers of two so a schedule compiles O(log max_batch)
kernel variants, not one per wave width.

Unlike the NumPy vector backend, masked per-hop reads/writes do not
require link-disjoint routes: hops are walked sequentially, so a route
may revisit a link.

**Whole-schedule scan path** (the default, DESIGN.md §5): on top of the
per-wave kernel this module also folds the *entire* wave plan into one
jitted ``lax.scan`` dispatch (``evaluate_plan``).  The engine emits the
complete level-batched plan up front (``engine.plan_waves``); the host
stages stacked per-wave inputs (task ids, predecessor ids + edge
indices, exit/real flags) plus the all-source route tensors
(``layout.stacked_src_tensors`` / ``stacked_edge_ct``), and the scan
body — pure ``jnp``, the exact op-for-op algebra of the per-wave kernel
— carries ``(link_free, proc_free, loads, loads/period, BP, aft,
proc_of)`` wave to wave, sorting each decision's predecessors by the
device-resident ``(aft, id)`` key (``jnp.lexsort``) and gathering their
source rows dynamically.  One upload, one launch, one blocking fetch
per schedule: host round-trips drop O(levels) -> O(1).  The HVLB_CC
alpha sweep folds in as one more batch axis (``evaluate_plan_sweep``):
a ``vmap`` over the alpha grid evaluates every alpha's schedule in the
same dispatch.  ``REPRO_PALLAS_SCAN=0`` falls back to the per-wave
kernel loop (which also serves single-decision ``evaluate`` protocol
calls and remains the numerics reference for the scan).

``n_launches`` / ``n_roundtrips`` / ``n_state_uploads`` count kernel
launches, blocking device->host transfers, and host->device state
re-uploads; ``benchmarks/exp7`` records launches per schedule and the
CI gate holds the per-schedule total at a constant (<= 3: upload,
dispatch, fetch) on the scan path and O(levels) on the per-wave path.
"""
from __future__ import annotations

import functools
import os
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .base import CandidateEvaluator, Decision
from ..faults import WaveTimeoutError
from .layout import (LANE, SUBLANE_F32, pad_dim, padded_edge_ct,
                     padded_src_tensors, src_layout, stacked_edge_ct,
                     stacked_src_tensors)

_INF = float("inf")
_NEG_INF = float("-inf")

# Documented f32 near-tie tolerance: two candidates whose selection
# values agree within this *relative* tolerance may resolve differently
# from the f64 scalar reference on the float32 device path (the winner
# is then the deterministic f32 argmin).  Chosen ~2 decades above the
# f32 epsilon (1.19e-7) so accumulated rounding across a schedule's
# worth of in-kernel commits stays inside it.
F32_NEAR_TIE_RTOL = 1e-5

# jitted kernel wrappers keyed by the padded static shape signature
# (B, K, R, H, P, L, f32?, interpret?): instances with the same padded
# dims share one trace/compile.  Bounded LRU — each entry pins a traced/
# compiled XLA executable, and a long-lived process scheduling many
# distinctly-shaped graphs would otherwise grow it forever.  Eviction is
# safe: rebuilding a kernel is deterministic, results never change.
_RUN_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_RUN_CACHE_MAX = 32


def _use_interpret() -> bool:
    """Interpreter-mode fallback: compiled Mosaic kernels need a TPU;
    everywhere else (CPU CI runners, GPU hosts) the kernel runs under
    the Pallas interpreter.  ``REPRO_PALLAS_INTERPRET=1/0`` forces."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _use_f32(interpret: bool) -> bool:
    """Kernel dtype: float32 on the compiled path (TPUs have no f64),
    float64 under the interpreter (keeps the scalar-reference arithmetic
    bit-for-bit).  ``REPRO_PALLAS_DTYPE=float32|float64`` forces — the
    f32 near-tie policy is tested by forcing f32 under the interpreter."""
    env = os.environ.get("REPRO_PALLAS_DTYPE")
    if env is not None:
        if env in ("float32", "f32"):
            return True
        if env in ("float64", "f64"):
            return False
        raise ValueError(f"REPRO_PALLAS_DTYPE={env!r}: expected float32 "
                         "or float64")
    return not interpret


def _use_tile(interpret: bool) -> bool:
    """Tile padding: on for a real Mosaic compile (P to sublane, L to
    lane multiples), off under the interpreter where it only costs time.
    ``REPRO_PALLAS_TILE=1/0`` forces (padding is arithmetic-neutral, so
    the padded shapes are exercised under the interpreter in CI)."""
    env = os.environ.get("REPRO_PALLAS_TILE")
    if env is not None:
        return env not in ("0", "false", "False")
    return not interpret


def _use_scan() -> bool:
    """Whole-schedule ``lax.scan`` dispatch (one launch per schedule)
    vs the per-wave kernel loop.  On by default — the two paths are
    decision-identical (f64) / near-tie-policy-identical (f32);
    ``REPRO_PALLAS_SCAN=0`` forces the per-wave loop (exp7 uses the
    toggle to time both)."""
    env = os.environ.get("REPRO_PALLAS_SCAN")
    if env is not None:
        return env not in ("0", "false", "False")
    return True


def _bucket(b: int) -> int:
    """Smallest power of two >= b (bounds compiled kernel variants)."""
    n = 1
    while n < b:
        n *= 2
    return n


def _batch_kernel(alpha_ref, period_ref, aft_ref, ct_ref, masks_ref,
                  valid_ref, nhops_ref, comp_ref, ldet_ref, flags_ref,
                  lf0_ref, pf0_ref, loads0_ref, lop0_ref, bp0_ref,
                  win_ref, est_ref, eft_ref, a_ref, b_ref,
                  lst_ref, lft_ref, bestr_ref,
                  lf_ref, pf_ref, loads_ref, lop_ref, bp_ref,
                  *, K: int, R: int, H: int, P: int, L: int):
    """One grid step = one decision of the wave (module docstring).

    The wave is a ``grid=(B,)`` launch: TPU (and interpreter) grids
    iterate **sequentially**, so the link/processor state committed by
    grid step ``b`` is exactly what step ``b+1`` reads — the carry lives
    in the state *output* blocks (``lf_ref`` ... ``bp_ref``), whose
    constant index map revisits the same VMEM block every step; step 0
    seeds them from the state inputs.  Per-decision inputs/outputs are
    blocked on the leading (decision) axis, so the traced body is
    independent of the wave width B.

    Static shapes: K padded predecessors x R padded routes x H padded
    hops over (P, L) tile-padded lanes/links; loops unroll at trace
    time.  Padding is arithmetic, not control flow: padded hops read
    ``-inf`` and add ``-inf`` CTML (the running maxima ignore them),
    padded routes mask to ``+inf`` arrival, padded predecessors carry
    ``aft = -inf`` and all-zero commit masks, padded processor lanes
    carry ``+inf`` computation cost (never win), and padded *decisions*
    (bucket tail) carry ``is_real = 0`` so their commit is a no-op —
    every padded contribution drops out of the exact max algebra.

    ``flags_ref[0] = (is_exit, is_real)``: exit tasks pass ``ldet = 1``
    rows and select on bare EFT (``BP`` forced to 1, so ``eft * 1 * 1``
    collapses exactly to the Def. 4.2 value).
    """
    f = lf0_ref.dtype
    neg = jnp.array(_NEG_INF, dtype=f)
    one = jnp.array(1.0, dtype=f)
    alpha = alpha_ref[0]
    period = period_ref[0]
    first = pl.program_id(0) == 0
    # state carry: seeded from the inputs at step 0, thereafter read
    # back from the revisited output blocks (select discards whatever
    # the unselected branch read, so the uninitialized step-0 output
    # read is harmless)
    lf = jnp.where(first, lf0_ref[:], lf_ref[:])
    pf = jnp.where(first, pf0_ref[:], pf_ref[:])
    loads = jnp.where(first, loads0_ref[:], loads_ref[:])
    lop = jnp.where(first, lop0_ref[:], lop_ref[:])
    bp = jnp.where(first, bp0_ref[:], bp_ref[:])
    idx = jax.lax.broadcasted_iota(jnp.int32, (P, 1), 0)[:, 0]

    lane = jnp.broadcast_to(lf, (P, L))
    arrival = jnp.full((P,), _NEG_INF, dtype=f)
    for k in range(K):
        aft_i = aft_ref[0, k]
        r_lst = []
        r_lft = []
        r_final = []
        for r in range(R):
            lst = lft = None
            lsts = []
            lfts = []
            for h in range(H):
                m = masks_ref[0, k, r, h]                # (P, L) one-hot
                avail = jnp.max(jnp.where(m > 0, lane, neg), axis=1)
                lst = jnp.maximum(avail, aft_i) if h == 0 \
                    else jnp.maximum(lst, avail)         # Eq. 13
                x = lst + ct_ref[0, k, r, h]
                lft = x if h == 0 else jnp.maximum(lft, x)   # Eq. 14
                lsts.append(lst)
                lfts.append(lft)
            r_lst.append(lsts)
            r_lft.append(lfts)
            r_final.append(jnp.where(valid_ref[0, k, r] > 0, lft, _INF))
        # lexicographic (LFT, hops, route-index) min per lane
        best_f = r_final[0]
        best_nh = nhops_ref[0, k, 0]
        best_r = jnp.zeros((P,), jnp.int32)
        for r in range(1, R):
            fv = r_final[r]
            nh = nhops_ref[0, k, r]
            better = (fv < best_f) | ((fv == best_f) & (nh < best_nh))
            best_f = jnp.where(better, fv, best_f)
            best_nh = jnp.where(better, nh, best_nh)
            best_r = jnp.where(better, jnp.int32(r), best_r)
        # commit the selected route per lane; LFT_h >= avail_h, so a
        # masked overwrite reproduces the scalar "write if greater"
        for h in range(H):
            sel_lst = r_lst[0][h]
            sel_lft = r_lft[0][h]
            sel_m = masks_ref[0, k, 0, h]
            for r in range(1, R):
                pick = best_r == r
                sel_lst = jnp.where(pick, r_lst[r][h], sel_lst)
                sel_lft = jnp.where(pick, r_lft[r][h], sel_lft)
                sel_m = jnp.where(pick[:, None],
                                  masks_ref[0, k, r, h], sel_m)
            lane = jnp.where(sel_m > 0, sel_lft[:, None], lane)
            lst_ref[0, k, h, :] = sel_lst
            lft_ref[0, k, h, :] = sel_lft
        bestr_ref[0, k, :] = best_r
        arrival = jnp.maximum(arrival, best_f)

    # ---- batched Eqs. 10-12 + Defs. 4.1-4.2 over all P lanes ----
    est = jnp.maximum(arrival, pf)                       # Eqs. 10-11
    eft = est + comp_ref[0]                              # Eq. 12
    a = eft * ldet_ref[0]
    is_exit = flags_ref[0, 0] > 0
    value = a * jnp.where(is_exit, one, bp)  # Def. 4.1 (exit: ldet=bp=1)
    # strict lexicographic (value, eft, proc) argmin, first-index ties
    vmin = jnp.min(value)
    tie = value == vmin
    emin = jnp.min(jnp.where(tie, eft, _INF))
    tie &= eft == emin
    w = jnp.min(jnp.where(tie, idx, jnp.int32(P)))
    win_ref[0] = w
    est_ref[0, :] = est
    eft_ref[0, :] = eft
    a_ref[0, :] = a
    b_ref[0, :] = a * lop            # pre-commit loads/period, as scalar
    # ---- in-kernel commit (the next grid step reads this state) ----
    real = flags_ref[0, 1] > 0
    onehot = (idx == w) & real
    # the winner lane's column of the lane buffer IS the committed
    # link state: masked overwrites only ever raise (LFT >= avail),
    # so the column equals the scalar path's max-folded commits
    win_col = jnp.max(jnp.where(onehot[:, None], lane, neg), axis=0)
    lf_ref[:] = jnp.where(real, win_col, lf)
    pf_ref[:] = jnp.where(onehot, eft, pf)
    loads = jnp.where(onehot, loads + comp_ref[0], loads)
    loads_ref[:] = loads
    lop = jnp.where(onehot, loads / period, lop)
    lop_ref[:] = lop
    bp_ref[:] = jnp.where(onehot, one + lop * alpha, bp)


def _compiled_run(B: int, K: int, R: int, H: int, P: int, L: int,
                  f32: bool, interpret: bool):
    key = (B, K, R, H, P, L, f32, interpret)
    run = _RUN_CACHE.pop(key, None)
    if run is None:
        kern = functools.partial(_batch_kernel, K=K, R=R, H=H, P=P, L=L)
        f = jnp.float32 if f32 else jnp.float64
        i32 = jnp.int32
        full = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))  # noqa: E731
        dec = lambda *shape: pl.BlockSpec((1,) + shape,  # noqa: E731
                                          lambda i: (i,) + (0,) * len(shape))
        in_specs = [
            full(1), full(1),                        # alpha, period
            dec(K),                                  # aft
            dec(K, R, H, P),                         # ct
            dec(K, R, H, P, L),                      # masks
            dec(K, R, P), dec(K, R, P),              # valid, nhops
            dec(P), dec(P),                          # comp, ldet
            dec(2),                                  # (is_exit, is_real)
            full(L), full(P), full(P), full(P), full(P),   # state in
        ]
        out_specs = (
            dec(),                                   # winner lane
            dec(P), dec(P), dec(P), dec(P),          # est, eft, A, B
            dec(K, H, P), dec(K, H, P),              # selected LST/LFT
            dec(K, P),                               # selected route
            full(L), full(P), full(P), full(P), full(P),   # state carry
        )
        out_shape = (
            jax.ShapeDtypeStruct((B,), i32),         # winner lane
            jax.ShapeDtypeStruct((B, P), f),         # est
            jax.ShapeDtypeStruct((B, P), f),         # eft
            jax.ShapeDtypeStruct((B, P), f),         # cand_A
            jax.ShapeDtypeStruct((B, P), f),         # cand_B
            jax.ShapeDtypeStruct((B, K, H, P), f),   # selected LST
            jax.ShapeDtypeStruct((B, K, H, P), f),   # selected LFT
            jax.ShapeDtypeStruct((B, K, P), i32),    # selected route
            jax.ShapeDtypeStruct((L,), f),           # link state carry
            jax.ShapeDtypeStruct((P,), f),           # proc_free carry
            jax.ShapeDtypeStruct((P,), f),           # loads carry
            jax.ShapeDtypeStruct((P,), f),           # loads/period carry
            jax.ShapeDtypeStruct((P,), f),           # BP carry
        )
        call = pl.pallas_call(kern, grid=(B,), in_specs=in_specs,
                              out_specs=out_specs, out_shape=out_shape,
                              interpret=interpret)

        def run(alpha, period, aft, cts, masks, valids, nhopss,
                comp, ldet, flags, lf, pf, loads, lop, bp):
            ct = jnp.stack(cts).reshape(B, K, R, H, P)
            m = jnp.stack(masks).reshape(B, K, R, H, P, L)
            v = jnp.stack(valids).reshape(B, K, R, P)
            nh = jnp.stack(nhopss).reshape(B, K, R, P)
            return call(alpha, period, aft, ct, m, v, nh,
                        comp, ldet, flags, lf, pf, loads, lop, bp)

        run = jax.jit(run)
    _RUN_CACHE[key] = run
    while len(_RUN_CACHE) > _RUN_CACHE_MAX:
        _RUN_CACHE.popitem(last=False)
    return run


def _scan_run(W: int, B: int, K: int, R: int, H: int, Pp: int, Lp: int,
              Np: int, Ep: int, A: int, f32: bool):
    """Jitted whole-schedule runner: ``lax.scan`` over ``W`` stacked
    waves of ``B`` decision slots (module docstring; DESIGN.md §5).

    Cached per **padded** static signature — ``(W, B)`` bucketed wave
    count/width, predecessor/route/hop maxima, tile-padded ``(Pp, Lp)``,
    bucketed task/edge counts ``(Np, Ep)``, and the bucketed alpha-grid
    width ``A`` (0 = no sweep axis) — so graphs with the same padded
    shape share one compilation.

    The scan body replays the per-wave kernel's algebra op for op; the
    only new arithmetic is *ordering*, not values: each slot sorts its
    predecessors by the device-carried ``(aft, id)`` key (the scalar
    reference's host-side sort — unknowable on the host here because a
    predecessor's AFT is decided inside the scan) and gathers that
    predecessor's route tensors by its carried placement.  Padded slots
    (``real = 0``), padded waves (all-pad rows) and padded predecessors
    (pad source plane ``P``, pad edge row ``Ep - 1``) drop out of the
    exact max algebra exactly like the per-wave pad tensors.

    With ``A > 0`` the whole scan is ``vmap``-ed over a ``(A,)`` alpha
    vector — the (A, B) fused sweep grid: every alpha's schedule
    evolves its own independent carry inside the same dispatch.
    """
    key = ("scan", W, B, K, R, H, Pp, Lp, Np, Ep, A, f32)
    run = _RUN_CACHE.pop(key, None)
    if run is not None:
        _RUN_CACHE[key] = run
        return run
    f = jnp.float32 if f32 else jnp.float64
    i32 = jnp.int32

    def schedule(alpha, period, task, real, pred, pvalid, edge, exitf,
                 masks_all, valid_all, nhops_all, ct_all, comp_all,
                 ldet_all, lf0, pf0, loads0, lop0, bp0, aft0, proc0):
        one = jnp.array(1.0, dtype=f)
        neg = jnp.array(_NEG_INF, dtype=f)
        pad_src = jnp.int32(masks_all.shape[0] - 1)
        idx = jax.lax.broadcasted_iota(jnp.int32, (Pp, 1), 0)[:, 0]

        def wave_step(carry, xs):
            lf, pf, loads, lop, bp, aft_t, proc_t = carry
            w_task, w_real, w_pred, w_pvalid, w_edge, w_exit = xs

            def slot(b, st):
                (lf, pf, loads, lop, bp, aft_t, proc_t,
                 win_o, est_o, eft_o, a_o, b_o, lst_o, lft_o,
                 bestr_o) = st
                j = w_task[b]
                is_real = w_real[b] > 0
                is_exit = w_exit[b] > 0
                pv = w_pvalid[b] > 0
                # the scalar reference's (aft, id) predecessor order,
                # computed on device from the carried AFT; invalid slots
                # sort last on the (+inf, Np) key and read the pad
                # source plane / pad edge row
                paft = jnp.where(pv, aft_t[w_pred[b]], _INF)
                pkey = jnp.where(pv, w_pred[b], jnp.int32(Np))
                perm = jnp.lexsort((pkey, paft))
                sp = w_pred[b][perm]
                spv = pv[perm]
                s_aft = jnp.where(spv, aft_t[sp], neg)
                s_src = jnp.where(spv, proc_t[sp], pad_src)
                s_edge = jnp.where(spv, w_edge[b][perm], jnp.int32(Ep - 1))

                comp_j = comp_all[j]
                ldet_j = ldet_all[j]
                lane = jnp.broadcast_to(lf, (Pp, Lp))
                arrival = jnp.full((Pp,), _NEG_INF, dtype=f)
                sel_lsts = []
                sel_lfts = []
                bestrs = []
                for k in range(K):
                    aft_i = s_aft[k]
                    m_k = masks_all[s_src[k]]
                    ct_k = ct_all[s_edge[k], s_src[k]]
                    v_k = valid_all[s_src[k]]
                    nh_k = nhops_all[s_src[k]]
                    r_lst = []
                    r_lft = []
                    r_final = []
                    for r in range(R):
                        lst = lft = None
                        lsts = []
                        lfts = []
                        for h in range(H):
                            m = m_k[r, h]                    # (Pp, Lp)
                            avail = jnp.max(jnp.where(m > 0, lane, neg),
                                            axis=1)
                            lst = jnp.maximum(avail, aft_i) if h == 0 \
                                else jnp.maximum(lst, avail)     # Eq. 13
                            x = lst + ct_k[r, h]
                            lft = x if h == 0 else jnp.maximum(lft, x)
                            lsts.append(lst)
                            lfts.append(lft)
                        r_lst.append(lsts)
                        r_lft.append(lfts)
                        r_final.append(jnp.where(v_k[r] > 0, lft, _INF))
                    best_f = r_final[0]
                    best_nh = nh_k[0]
                    best_r = jnp.zeros((Pp,), jnp.int32)
                    for r in range(1, R):
                        fv = r_final[r]
                        nh = nh_k[r]
                        better = (fv < best_f) | ((fv == best_f) &
                                                  (nh < best_nh))
                        best_f = jnp.where(better, fv, best_f)
                        best_nh = jnp.where(better, nh, best_nh)
                        best_r = jnp.where(better, jnp.int32(r), best_r)
                    sl = []
                    sf = []
                    for h in range(H):
                        sel_lst = r_lst[0][h]
                        sel_lft = r_lft[0][h]
                        sel_m = m_k[0, h]
                        for r in range(1, R):
                            pick = best_r == r
                            sel_lst = jnp.where(pick, r_lst[r][h], sel_lst)
                            sel_lft = jnp.where(pick, r_lft[r][h], sel_lft)
                            sel_m = jnp.where(pick[:, None], m_k[r, h],
                                              sel_m)
                        lane = jnp.where(sel_m > 0, sel_lft[:, None], lane)
                        sl.append(sel_lst)
                        sf.append(sel_lft)
                    sel_lsts.append(jnp.stack(sl))
                    sel_lfts.append(jnp.stack(sf))
                    bestrs.append(best_r)
                    arrival = jnp.maximum(arrival, best_f)

                est = jnp.maximum(arrival, pf)               # Eqs. 10-11
                eft = est + comp_j                           # Eq. 12
                a = eft * ldet_j
                value = a * jnp.where(is_exit, one, bp)      # Def. 4.2
                vmin = jnp.min(value)
                tie = value == vmin
                emin = jnp.min(jnp.where(tie, eft, _INF))
                tie &= eft == emin
                w = jnp.min(jnp.where(tie, idx, jnp.int32(Pp)))
                cb = a * lop         # pre-commit loads/period, as scalar
                onehot = (idx == w) & is_real
                win_col = jnp.max(jnp.where(onehot[:, None], lane, neg),
                                  axis=0)
                lf = jnp.where(is_real, win_col, lf)
                pf = jnp.where(onehot, eft, pf)
                loads = jnp.where(onehot, loads + comp_j, loads)
                lop = jnp.where(onehot, loads / period, lop)
                bp = jnp.where(onehot, one + lop * alpha, bp)  # Def. 4.1
                eft_w = eft[w]
                aft_t = aft_t.at[j].set(jnp.where(is_real, eft_w,
                                                  aft_t[j]))
                proc_t = proc_t.at[j].set(jnp.where(is_real, w,
                                                    proc_t[j]))
                win_o = win_o.at[b].set(w)
                est_o = est_o.at[b].set(est)
                eft_o = eft_o.at[b].set(eft)
                a_o = a_o.at[b].set(a)
                b_o = b_o.at[b].set(cb)
                lst_o = lst_o.at[b].set(jnp.stack(sel_lsts))
                lft_o = lft_o.at[b].set(jnp.stack(sel_lfts))
                bestr_o = bestr_o.at[b].set(jnp.stack(bestrs))
                return (lf, pf, loads, lop, bp, aft_t, proc_t,
                        win_o, est_o, eft_o, a_o, b_o, lst_o, lft_o,
                        bestr_o)

            st = (lf, pf, loads, lop, bp, aft_t, proc_t,
                  jnp.zeros((B,), i32),
                  jnp.zeros((B, Pp), f), jnp.zeros((B, Pp), f),
                  jnp.zeros((B, Pp), f), jnp.zeros((B, Pp), f),
                  jnp.zeros((B, K, H, Pp), f),
                  jnp.zeros((B, K, H, Pp), f),
                  jnp.zeros((B, K, Pp), i32))
            st = jax.lax.fori_loop(0, B, slot, st)
            lf, pf, loads, lop, bp, aft_t, proc_t = st[:7]
            return (lf, pf, loads, lop, bp, aft_t, proc_t), st[7:]

        carry0 = (lf0, pf0, loads0, lop0, bp0, aft0, proc0)
        xs = (task, real, pred, pvalid, edge, exitf)
        _, ys = jax.lax.scan(wave_step, carry0, xs)
        return ys

    if A:
        def run(alphas, period, task, real, pred, pvalid, edge, exitf,
                masks_all, valid_all, nhops_all, ct_all, comp_all,
                ldet_all, lf0, pf0, loads0, lop0, bp0, aft0, proc0):
            def one(al):
                return schedule(al, period, task, real, pred, pvalid,
                                edge, exitf, masks_all, valid_all,
                                nhops_all, ct_all, comp_all, ldet_all,
                                lf0, pf0, loads0, lop0, bp0, aft0, proc0)
            return jax.vmap(one)(alphas)

        run = jax.jit(run)
    else:
        run = jax.jit(schedule)
    _RUN_CACHE[key] = run
    while len(_RUN_CACHE) > _RUN_CACHE_MAX:
        _RUN_CACHE.popitem(last=False)
    return run


class PallasBackend(CandidateEvaluator):
    """Device-batched candidate evaluation: one Pallas kernel per wave."""

    name = "pallas"

    def __init__(self, inst) -> None:
        super().__init__(inst)
        self._interpret = _use_interpret()
        self._f32 = _use_f32(self._interpret)
        self._tile = _use_tile(self._interpret)
        self._np_dtype = np.float32 if self._f32 else np.float64
        self._dtype = jnp.float32 if self._f32 else jnp.float64
        P = inst.P
        self._L = L = max(1, inst._n_links)
        # instance-global padded dims so per-pred tensors stack; tile
        # padding (sublane P, lane L) only when targeting Mosaic
        lays = [src_layout(inst, s) for s in range(P)]
        self._R = max(l.R for l in lays)
        self._H = max(l.H for l in lays)
        self._K = max([1] + [len(p) for p in inst._preds])
        self._Pp = pad_dim(P, SUBLANE_F32) if self._tile else P
        self._Lp = pad_dim(L, LANE) if self._tile else L
        self._src_dev: Dict[int, Tuple[jax.Array, jax.Array, jax.Array]] = {}
        self._ct_dev: Dict[Tuple[int, int, int], jax.Array] = {}
        # padding predecessor: aft = -inf, zero masks, -inf CTML, one
        # valid zero-hop route -> arrival/commit no-ops
        R, H, Pp, Lp = self._R, self._H, self._Pp, self._Lp
        pad_ct = np.full((R, H, Pp), _NEG_INF)
        pad_valid = np.zeros((R, Pp))
        pad_valid[0] = 1.0
        self._pad = (self._to_dev(pad_ct),
                     self._to_dev(np.zeros((R, H, Pp, Lp))),
                     self._to_dev(pad_valid),
                     self._to_dev(np.zeros((R, Pp))))
        # comp rows padded with +inf lanes (padded lanes never win);
        # ldet rows: exit tasks and padded lanes read exactly 1.0
        comp_pad = np.full((inst.n, Pp), _INF)
        comp_pad[:, :P] = inst.comp
        ldet_pad = np.ones((inst.n, Pp))
        ldet_pad[:, :P] = inst.ldet
        ldet_pad[inst._is_exit, :] = 1.0
        self._comp_rows = comp_pad.astype(self._np_dtype)
        self._ldet_rows = ldet_pad.astype(self._np_dtype)
        # scan-path consts: bucketed task/edge axes for the carried
        # aft/proc arrays and the stacked all-edge CT table; the device
        # stacks themselves are built lazily on the first plan dispatch
        self._Np = _bucket(inst.n)
        self._Ep = _bucket(len(inst._edge_index) + 1)
        self._scan_dev: Optional[tuple] = None
        self._scan_in_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        # instrumentation (read by benchmarks/exp7 and the tests)
        self.n_launches = 0
        self.n_roundtrips = 0
        self.n_state_uploads = 0

    # ------------------------------------------------------------ device
    def _to_dev(self, arr: np.ndarray) -> jax.Array:
        """Upload a float array in the kernel dtype (f64 needs the scoped
        x64 switch so jnp does not silently truncate)."""
        arr = np.asarray(arr, dtype=self._np_dtype)
        if self._f32:
            return jnp.asarray(arr)
        with jax.experimental.enable_x64():
            return jnp.asarray(arr)

    # ------------------------------------------------------------- state
    def _alloc(self) -> None:
        inst = self.inst
        P, L = inst.P, self._L
        self.link_free = np.zeros(L, dtype=np.float64)   # host mirror
        self.proc_free = np.zeros(P, dtype=np.float64)
        self.loads = np.zeros(P, dtype=np.float64)
        self._lop = np.zeros(P, dtype=np.float64)
        self._bp = np.ones(P, dtype=np.float64)
        # device state carry (link_free, proc_free, loads, loads/period,
        # BP) — built from the host mirrors on first use and after any
        # host-side commit (trace replay), then carried launch-to-launch
        self._state: Optional[tuple] = None
        self._state_dirty = True

    def _upload_state(self) -> None:
        """(Re)build the device state carry from the float64 host
        mirrors — one transfer, paid at run start and after a trace
        replay committed host-side (on the f64 path mirrors and device
        state are bit-equal, so the re-upload is value-neutral)."""
        P, Pp, L, Lp = self.inst.P, self._Pp, self._L, self._Lp
        lf = np.zeros(Lp)
        lf[:L] = self.link_free
        pf = np.zeros(Pp)
        pf[:P] = self.proc_free
        loads = np.zeros(Pp)
        loads[:P] = self.loads
        lop = np.zeros(Pp)
        lop[:P] = self._lop
        bp = np.ones(Pp)
        bp[:P] = self._bp
        self._state = tuple(self._to_dev(x)
                            for x in (lf, pf, loads, lop, bp))
        self._state_dirty = False
        self.n_state_uploads += 1

    def _commit_host(self, j: int, p: int, est: float, eft: float,
                     msgs: list) -> None:
        """Mirror one in-kernel commit on the host: the shared scalar
        ``apply`` plus the incremental Def.-4.1 terms — same floats in
        the same order as any other backend, which is what keeps traces
        recorded here replayable anywhere."""
        CandidateEvaluator.apply(self, j, p, est, eft, msgs)
        lop = self.loads[p] / self.period
        self._lop[p] = lop
        self._bp[p] = 1.0 + lop * self.alpha

    def apply(self, j: int, p: int, est: float, eft: float,
              msgs: list) -> None:
        """Trace-replay commit: host mirrors only; the device carry is
        marked stale and re-uploaded wholesale before the next launch
        (replaying n records costs one transfer, not n scatters)."""
        self._commit_host(j, p, est, eft, msgs)
        self._state_dirty = True

    # ----------------------------------------------------- device consts
    def _src_tensors(self, src: int):
        """One-hot hop masks + route validity/hop counts of ``src``,
        padded to the instance-global (R, H, Pp, Lp) and device-resident
        (shaped by the shared ``layout`` precompute, uploaded once)."""
        dev = self._src_dev.get(src)
        if dev is None:
            masks, valid, nhops = padded_src_tensors(
                self.inst, src, self._R, self._H, self._Pp, self._Lp)
            dev = (self._to_dev(masks), self._to_dev(valid),
                   self._to_dev(nhops))
            self._src_dev[src] = dev
        return dev

    def _edge_tensor(self, i: int, j: int, src: int) -> jax.Array:
        """Device CTML tensor (R, H, Pp) of edge ``e_ij`` from ``src``,
        a padded view of the shared all-edge table, uploaded once."""
        ct = self._ct_dev.get((i, j, src))
        if ct is None:
            ct = self._to_dev(padded_edge_ct(
                self.inst, self.inst._src_layouts[src], i, j,
                self._R, self._H, self._Pp))
            self._ct_dev[(i, j, src)] = ct
        return ct

    # ---------------------------------------------------------- evaluate
    def _run_batch(self, js: Sequence[int], commit: bool) -> List[Decision]:
        """Stage one wave, launch one kernel, decode one transfer."""
        inst = self.inst
        P = inst.P
        aft = self.aft
        proc_of = self.proc_of
        K = self._K
        if self._state_dirty:
            self._upload_state()

        B = len(js)
        Bp = _bucket(B)
        pad_ct, pad_masks, pad_valid, pad_nhops = self._pad
        cts, masks, valids, nhopss = [], [], [], []
        aft_rows = np.full((Bp, K), _NEG_INF)
        flags = np.zeros((Bp, 2))
        preds_of: List[list] = []
        srcs_of: List[list] = []
        comp_rows = np.empty((Bp, self._Pp), dtype=self._np_dtype)
        ldet_rows = np.ones((Bp, self._Pp), dtype=self._np_dtype)
        for b, j in enumerate(js):
            preds = inst._preds[j]
            if len(preds) > 1:
                preds = sorted(preds, key=lambda i: (aft[i], i))
            srcs = [proc_of[i] for i in preds]
            preds_of.append(preds)
            srcs_of.append(srcs)
            for k, (i, src) in enumerate(zip(preds, srcs)):
                m, v, nh = self._src_tensors(src)
                cts.append(self._edge_tensor(i, j, src))
                masks.append(m)
                valids.append(v)
                nhopss.append(nh)
                aft_rows[b, k] = aft[i]
            for _ in range(K - len(preds)):
                cts.append(pad_ct)
                masks.append(pad_masks)
                valids.append(pad_valid)
                nhopss.append(pad_nhops)
            comp_rows[b] = self._comp_rows[j]
            ldet_rows[b] = self._ldet_rows[j]
            flags[b, 0] = 1.0 if inst._is_exit[j] else 0.0
            flags[b, 1] = 1.0 if commit else 0.0
        if Bp > B:                       # bucket padding: no-op decisions
            # finite comp rows keep the padded winner math inf-free; the
            # is_real = 0 flag (zeros-initialized) voids their commit
            comp_rows[B:] = self._comp_rows[js[0]]
            ldet_rows[B:] = 1.0
            for _ in range((Bp - B) * K):
                cts.append(pad_ct)
                masks.append(pad_masks)
                valids.append(pad_valid)
                nhopss.append(pad_nhops)

        run = _compiled_run(Bp, K, self._R, self._H, self._Pp, self._Lp,
                            self._f32, self._interpret)
        dt = self._np_dtype
        args = (np.asarray([self.alpha], dtype=dt),
                np.asarray([self.period], dtype=dt),
                aft_rows.astype(dt), tuple(cts), tuple(masks),
                tuple(valids), tuple(nhopss), comp_rows, ldet_rows,
                flags.astype(dt), *self._state)
        if self._f32:
            out = run(*args)
        else:
            # scoped x64: without it jit canonicalizes the f64 inputs
            # (and the kernel trace) down to f32
            with jax.experimental.enable_x64():
                out = run(*args)
        self.n_launches += 1
        if commit:
            # the state carry stays on device — never fetched
            self._state = tuple(out[8:])
        win, est, eft, ca_all, cb_all, lst, lft, bestr = \
            jax.device_get(out[:8])  # analysis: allow[host-sync] the documented one-per-wave transfer (DESIGN.md §5); state carry stays on device
        self.n_roundtrips += 1

        decisions: List[Decision] = []
        for b, j in enumerate(js):
            p = int(win[b])
            msgs = []
            for k, (i, src) in enumerate(zip(preds_of[b], srcs_of[b])):
                if src == p:
                    continue
                r = int(bestr[b, k, p])
                lids, robj = inst._src_layouts[src].route_meta[p][r]
                msgs.append((i, robj,
                             [(lids[h], float(lst[b, k, h, p]),
                               float(lft[b, k, h, p]))
                              for h in range(len(lids))]))
            track = self.want_bound and not inst._is_exit[j]
            if track:
                ca = tuple(float(x) for x in ca_all[b, :P])
                cb = tuple(float(x) for x in cb_all[b, :P])
                contrib = self.crossing(p, ca, cb, self.alpha)
            else:
                ca = cb = None
                contrib = _INF
            d = (p, float(est[b, p]), float(eft[b, p]), msgs, ca, cb,
                 contrib)
            if commit:
                # keep the f64 host mirrors in lockstep via the shared
                # scalar commit (bit-equal to the device carry on the
                # f64 path; the authority for trace replay either way)
                self._commit_host(j, d[0], d[1], d[2], d[3])
            decisions.append(d)
        return decisions

    def evaluate_batch(self, js: Sequence[int]) -> List[Decision]:
        return self._run_batch(js, commit=True)

    def evaluate(self, j: int) -> Decision:
        # protocol compatibility: a single non-committing evaluation —
        # the kernel runs with is_real = 0, so the device carry passes
        # through unchanged and the caller commits via apply()
        return self._run_batch([j], commit=False)[0]

    # ----------------------------------------------- whole-schedule scan
    def _scan_tables(self) -> tuple:
        """Device-resident all-source/all-edge stacks for the scan's
        dynamic gathers (built once per backend; a few MB at exp7
        scale).  Task-indexed comp/ldet rows are padded to the bucketed
        ``Np`` (pad rows are never gathered — task ids are < n)."""
        if self._scan_dev is None:
            inst = self.inst
            n, Np = inst.n, self._Np
            masks, valid, nhops = stacked_src_tensors(
                inst, self._R, self._H, self._Pp, self._Lp)
            ct = stacked_edge_ct(inst, self._R, self._H, self._Pp,
                                 self._Ep)
            comp = np.zeros((Np, self._Pp))
            comp[:n] = self._comp_rows
            ldet = np.ones((Np, self._Pp))
            ldet[:n] = self._ldet_rows
            self._scan_dev = tuple(
                self._to_dev(x)
                for x in (masks, valid, nhops, ct, comp, ldet))
        return self._scan_dev

    def _scan_inputs(self, waves: Sequence[Sequence[int]]) -> tuple:
        """Stacked per-wave scan inputs (task/pred/edge ids + flags),
        bucket-padded on both the wave and slot axes; predecessors stay
        in graph order — the scan body sorts them by the carried
        ``(aft, id)`` key.  Cached per wave plan (a session re-plans the
        same queue; ``update()`` suffixes add a handful of entries)."""
        key = tuple(tuple(w) for w in waves)
        cached = self._scan_in_cache.pop(key, None)
        if cached is not None:
            self._scan_in_cache[key] = cached
            return cached
        inst = self.inst
        K, Ep = self._K, self._Ep
        Wp = _bucket(len(waves))
        Bp = _bucket(max(len(w) for w in waves))
        task = np.zeros((Wp, Bp), np.int32)
        real = np.zeros((Wp, Bp))
        pred = np.zeros((Wp, Bp, K), np.int32)
        pvalid = np.zeros((Wp, Bp, K))
        edge = np.full((Wp, Bp, K), Ep - 1, np.int32)
        exitf = np.zeros((Wp, Bp))
        eidx = inst._edge_index
        for wv, js in enumerate(waves):
            for b, j in enumerate(js):
                task[wv, b] = j
                real[wv, b] = 1.0
                if inst._is_exit[j]:
                    exitf[wv, b] = 1.0
                for k, i in enumerate(inst._preds[j]):
                    pred[wv, b, k] = i
                    pvalid[wv, b, k] = 1.0
                    edge[wv, b, k] = eidx[(i, j)]
        cached = (Wp, Bp, task, real, pred, pvalid, edge, exitf)
        self._scan_in_cache[key] = cached
        while len(self._scan_in_cache) > 8:
            self._scan_in_cache.popitem(last=False)
        return cached

    def _scan_dispatch(self, waves: Sequence[Sequence[int]],
                       alphas: Optional[Sequence[float]]) -> tuple:
        """Stage, launch, and fetch one whole-schedule scan: the initial
        carry comes from the f64 host mirrors (so a replayed trace
        prefix is already folded in), and the single blocking fetch
        returns every wave's winner/EST/EFT/LST/LFT/route arrays."""
        inst = self.inst
        P, Pp, L, Lp = inst.P, self._Pp, self._L, self._Lp
        n, Np = inst.n, self._Np
        Wp, Bp, task, real, pred, pvalid, edge, exitf = \
            self._scan_inputs(waves)
        consts = self._scan_tables()
        dt = self._np_dtype
        lf = np.zeros(Lp)
        lf[:L] = self.link_free
        pf = np.zeros(Pp)
        pf[:P] = self.proc_free
        loads = np.zeros(Pp)
        loads[:P] = self.loads
        lop = np.zeros(Pp)
        lop[:P] = self._lop
        bp = np.ones(Pp)
        bp[:P] = self._bp
        aft0 = np.zeros(Np)
        aft0[:n] = self.aft
        # unscheduled tasks point at the pad source plane P (only ever
        # gathered through a scheduled predecessor, but a negative index
        # would wrap)
        proc0 = np.full(Np, P, np.int32)
        proc0[:n] = [p if p >= 0 else P for p in self.proc_of]
        if alphas is None:
            Ap = 0
            a_arg = np.asarray(self.alpha, dtype=dt)
        else:
            Ap = _bucket(len(alphas))
            a_arg = np.asarray(
                list(alphas) + [alphas[-1]] * (Ap - len(alphas)),
                dtype=dt)
        run = _scan_run(Wp, Bp, self._K, self._R, self._H, Pp, Lp, Np,
                        self._Ep, Ap, self._f32)
        args = (a_arg, np.asarray(self.period, dtype=dt),
                task, real.astype(dt), pred, pvalid.astype(dt), edge,
                exitf.astype(dt), *consts,
                lf.astype(dt), pf.astype(dt), loads.astype(dt),
                lop.astype(dt), bp.astype(dt), aft0.astype(dt), proc0)
        if self._f32:
            out = run(*args)
        else:
            with jax.experimental.enable_x64():
                out = run(*args)
        self.n_launches += 1
        self.n_state_uploads += 1    # the initial-carry staging above
        fetched = jax.device_get(out)  # analysis: allow[host-sync] the documented one-per-SCHEDULE transfer (DESIGN.md §5); all decisions decode from this single fetch
        self.n_roundtrips += 1
        return tuple(fetched)

    def _decode_scan(self, waves: Sequence[Sequence[int]], outs: tuple,
                     alpha: float, commit: bool,
                     want_bound: bool) -> List[List[Decision]]:
        """Decode one schedule's fetched scan outputs into per-wave
        decision lists.  The host re-derives each decision's sorted
        predecessor order from the (already decoded) committed AFT
        mirrors — f64 -> kernel-dtype casting is monotone, so it matches
        the device's ``(aft, id)`` sort on the f64 path exactly (and
        within the near-tie policy on f32)."""
        inst = self.inst
        P = inst.P
        win, est, eft, ca_all, cb_all, lst, lft, bestr = outs
        if commit:
            aft_l, proc_l = self.aft, self.proc_of
        else:
            aft_l, proc_l = list(self.aft), list(self.proc_of)
        out: List[List[Decision]] = []
        for wv, js in enumerate(waves):
            ds: List[Decision] = []
            for b, j in enumerate(js):
                p = int(win[wv, b])
                preds = inst._preds[j]
                if len(preds) > 1:
                    preds = sorted(preds, key=lambda i: (aft_l[i], i))
                msgs = []
                for k, i in enumerate(preds):
                    src = proc_l[i]
                    if src == p:
                        continue
                    r = int(bestr[wv, b, k, p])
                    lids, robj = inst._src_layouts[src].route_meta[p][r]
                    msgs.append((i, robj,
                                 [(lids[h], float(lst[wv, b, k, h, p]),
                                   float(lft[wv, b, k, h, p]))
                                  for h in range(len(lids))]))
                track = want_bound and not inst._is_exit[j]
                if track:
                    ca = tuple(float(x) for x in ca_all[wv, b, :P])
                    cb = tuple(float(x) for x in cb_all[wv, b, :P])
                    contrib = self.crossing(p, ca, cb, alpha)
                else:
                    ca = cb = None
                    contrib = _INF
                d: Decision = (p, float(est[wv, b, p]),
                               float(eft[wv, b, p]), msgs, ca, cb,
                               contrib)
                if commit:
                    # f64 host mirrors in lockstep, as on the wave path
                    self._commit_host(j, d[0], d[1], d[2], d[3])
                else:
                    # sweep decode: per-alpha locals only — the run
                    # state must stay untouched
                    proc_l[j] = p
                    aft_l[j] = d[2]
                ds.append(d)
            out.append(ds)
        return out

    def evaluate_plan(self, waves: Sequence[Sequence[int]],
                      timeout: Optional[float] = None,
                      bid0: int = 0) -> List[List[Decision]]:
        """One ``lax.scan`` dispatch for the whole plan (module
        docstring); falls back to the per-wave kernel loop when
        ``REPRO_PALLAS_SCAN=0``.  The watchdog compares the single
        dispatch against the aggregate budget ``timeout * len(waves)``.
        """
        if not _use_scan() or not waves:
            return super().evaluate_plan(waves, timeout=timeout,
                                         bid0=bid0)
        t0 = time.monotonic()
        outs = self._scan_dispatch(waves, None)
        if timeout is not None:
            elapsed = time.monotonic() - t0
            budget = timeout * len(waves)
            if elapsed > budget:
                raise WaveTimeoutError(bid0, elapsed, budget)
        # the per-wave device carry is now stale relative to the
        # mirrors; any later per-wave launch re-uploads first
        self._state_dirty = True
        return self._decode_scan(waves, outs, self.alpha, True,
                                 self.want_bound)

    def supports_plan_sweep(self) -> bool:
        return _use_scan()

    def evaluate_plan_sweep(self, waves: Sequence[Sequence[int]],
                            alphas: Sequence[float], period: float,
                            timeout: Optional[float] = None
                            ) -> List[List[List[Decision]]]:
        """The (A, B) fused sweep: one ``vmap``-ed scan dispatch
        evaluates every alpha's whole schedule (module docstring).
        Decodes each alpha against its own local aft/proc arrays — run
        state is never committed."""
        alphas = list(alphas)
        if not alphas:
            return []
        if not waves:
            return [[] for _ in alphas]
        t0 = time.monotonic()
        outs = self._scan_dispatch(waves, alphas)
        if timeout is not None:
            elapsed = time.monotonic() - t0
            budget = timeout * len(waves) * len(alphas)
            if elapsed > budget:
                raise WaveTimeoutError(0, elapsed, budget)
        return [self._decode_scan(waves, tuple(o[ai] for o in outs),
                                  alpha, False, True)
                for ai, alpha in enumerate(alphas)]
