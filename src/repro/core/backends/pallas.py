"""JAX/Pallas candidate-evaluation backend: one device kernel per decision.

Evaluates all ``P`` placement candidates of one dequeued task in a
single :func:`pallas_call`.  The route tensors (hop one-hot masks over
the link axis, CTML rows, route validity/hop counts — all derived from
the shared :mod:`.layout` precompute) and the committed link state live
as device arrays; per decision the kernel

  1. broadcasts the committed ``(L,)`` link state into a ``(P, L)``
     *lane buffer* (lane ``p`` = candidate processor ``p``'s tentative
     link state),
  2. walks the task's predecessors in the scalar reference's
     ``(aft, id)`` order; per predecessor it runs the Eq. 13-14
     recurrences as **masked row ops** — ``avail_h`` is a masked max
     over the link axis, ``LST``/``LFT`` are running ``(P,)`` maxima —
     selects the best route per lane by the lexicographic
     ``(LFT, hops, index)`` rule, and commits the winning route's hop
     LFTs back into the lane buffer (masked writes),
  3. batches Eqs. 10-12 and Defs. 4.1-4.2 over all lanes and picks the
     strict lexicographic ``(value, EFT, proc)`` argmin winner.

The host decision layer receives the winner tuple plus the winner's
per-hop ``(LST, LFT)`` rows (for ``MessagePlacement``/trace records)
and the per-candidate linear coefficients ``(A_p, B_p)`` for the alpha
crossing bound, which is evaluated by the *shared* scalar
:meth:`~.base.CandidateEvaluator.crossing`.  Committing a decision
updates the host mirrors through the shared scalar ``apply`` and the
device link state through an exact scatter-``max`` — so the device copy
stays bit-equal to the host mirror between decisions and trace replay
works unchanged (traces remain backend-portable).

Precision: all arrays are ``float64``, enabled *scopedly* via
``jax.experimental.enable_x64()`` so importing this backend does not
flip the process-global x64 flag.  On CPU-only hosts (CI) the kernel
runs in interpreter mode (``pallas_call(..., interpret=True)``, forced
on/off by ``REPRO_PALLAS_INTERPRET=1/0``); there every operation is the
same IEEE-754 double arithmetic as the scalar reference — in practice
bit-identical, asserted decision-identical with float-tolerance
makespans (``tests/test_backend_equivalence.py``).  A compiled TPU run
would execute in ``float32`` (TPUs have no f64) with tile-padded
shapes; that relaxes the contract to decision-identity modulo f32
rounding and is not exercised by the tier-1 suite.

Unlike the NumPy vector backend, masked per-hop reads/writes do not
require link-disjoint routes: hops are walked sequentially, so a route
may revisit a link.

Per-decision dispatch cost is high (one kernel launch plus the stacked
route tensors of the task's predecessors); this backend is the
correctness-first device groundwork, opt-in via ``backend="pallas"``
(``"auto"`` never selects it).
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .base import CandidateEvaluator, Decision
from .layout import SrcLayout, edge_ct, src_layout

_INF = float("inf")
_NEG_INF = float("-inf")


# jitted kernel wrappers keyed by the static shape signature: instances
# with the same padded dims share one trace/compile (a fresh jit wrapper
# per backend instance would re-trace the kernel for every graph)
_RUN_CACHE: Dict[Tuple[int, int, int, int, int, bool], object] = {}


def _use_interpret() -> bool:
    """Interpreter-mode fallback: compiled Mosaic kernels need a TPU;
    everywhere else (CPU CI runners, GPU hosts) the kernel runs under
    the Pallas interpreter.  ``REPRO_PALLAS_INTERPRET=1/0`` forces."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _decision_kernel(aft_ref, ct_ref, masks_ref, valid_ref, nhops_ref,
                     lf_ref, pf_ref, comp_ref, ldet_ref, bp_ref, lop_ref,
                     win_ref, est_ref, eft_ref, a_ref, b_ref,
                     lst_ref, lft_ref, bestr_ref,
                     *, K: int, R: int, H: int, P: int, L: int):
    """All-candidate evaluation of one decision (see module docstring).

    Static shapes: K padded predecessors x R padded routes x H padded
    hops; predecessor/route/hop loops unroll at trace time.  Padding is
    arithmetic, not control flow: padded hops read ``-inf`` and add
    ``-inf`` CTML (the running maxima ignore them), padded routes mask
    to ``+inf`` arrival, padded predecessors carry ``aft = -inf`` and
    all-zero commit masks, so every padded contribution is a no-op of
    the exact max algebra.
    """
    neg = jnp.array(_NEG_INF, dtype=lf_ref.dtype)
    # lane buffer: every candidate lane starts from the committed state
    lane = jnp.broadcast_to(lf_ref[:], (P, L))
    arrival = jnp.full((P,), _NEG_INF, dtype=lf_ref.dtype)
    for k in range(K):
        aft_i = aft_ref[k]
        r_lst = []
        r_lft = []
        r_final = []
        for r in range(R):
            lst = lft = None
            lsts = []
            lfts = []
            for h in range(H):
                m = masks_ref[k, r, h]                       # (P, L) one-hot
                avail = jnp.max(jnp.where(m > 0, lane, neg), axis=1)
                lst = jnp.maximum(avail, aft_i) if h == 0 \
                    else jnp.maximum(lst, avail)             # Eq. 13
                x = lst + ct_ref[k, r, h]
                lft = x if h == 0 else jnp.maximum(lft, x)   # Eq. 14
                lsts.append(lst)
                lfts.append(lft)
            r_lst.append(lsts)
            r_lft.append(lfts)
            r_final.append(jnp.where(valid_ref[k, r] > 0, lft, _INF))
        # lexicographic (LFT, hops, route-index) min per lane
        best_f = r_final[0]
        best_nh = nhops_ref[k, 0]
        best_r = jnp.zeros((P,), jnp.int32)
        for r in range(1, R):
            f = r_final[r]
            nh = nhops_ref[k, r]
            better = (f < best_f) | ((f == best_f) & (nh < best_nh))
            best_f = jnp.where(better, f, best_f)
            best_nh = jnp.where(better, nh, best_nh)
            best_r = jnp.where(better, jnp.int32(r), best_r)
        # commit the selected route per lane; LFT_h >= avail_h, so a
        # masked overwrite reproduces the scalar "write if greater"
        for h in range(H):
            sel_lst = r_lst[0][h]
            sel_lft = r_lft[0][h]
            sel_m = masks_ref[k, 0, h]
            for r in range(1, R):
                pick = best_r == r
                sel_lst = jnp.where(pick, r_lst[r][h], sel_lst)
                sel_lft = jnp.where(pick, r_lft[r][h], sel_lft)
                sel_m = jnp.where(pick[:, None], masks_ref[k, r, h], sel_m)
            lane = jnp.where(sel_m > 0, sel_lft[:, None], lane)
            lst_ref[k, h, :] = sel_lst
            lft_ref[k, h, :] = sel_lft
        bestr_ref[k, :] = best_r
        arrival = jnp.maximum(arrival, best_f)

    # ---- batched Eqs. 10-12 + Defs. 4.1-4.2 over all P lanes ----
    est = jnp.maximum(arrival, pf_ref[:])                    # Eqs. 10-11
    eft = est + comp_ref[:]                                  # Eq. 12
    a = eft * ldet_ref[:]
    value = a * bp_ref[:]        # Def. 4.1 (exit tasks: ldet = bp = 1)
    b = a * lop_ref[:]
    # strict lexicographic (value, eft, proc) argmin, first-index ties
    vmin = jnp.min(value)
    tie = value == vmin
    emin = jnp.min(jnp.where(tie, eft, _INF))
    tie &= eft == emin
    idx = jax.lax.broadcasted_iota(jnp.int32, (P, 1), 0)[:, 0]
    win_ref[0] = jnp.min(jnp.where(tie, idx, jnp.int32(P)))
    est_ref[:] = est
    eft_ref[:] = eft
    a_ref[:] = a
    b_ref[:] = b


def _compiled_run(K: int, R: int, H: int, P: int, L: int,
                  interpret: bool):
    key = (K, R, H, P, L, interpret)
    run = _RUN_CACHE.get(key)
    if run is not None:
        return run
    kern = functools.partial(_decision_kernel, K=K, R=R, H=H, P=P, L=L)
    f64, i32 = jnp.float64, jnp.int32
    out_shape = (
        jax.ShapeDtypeStruct((1,), i32),         # winner lane
        jax.ShapeDtypeStruct((P,), f64),         # est
        jax.ShapeDtypeStruct((P,), f64),         # eft
        jax.ShapeDtypeStruct((P,), f64),         # cand_A
        jax.ShapeDtypeStruct((P,), f64),         # cand_B
        jax.ShapeDtypeStruct((K, H, P), f64),    # selected LST
        jax.ShapeDtypeStruct((K, H, P), f64),    # selected LFT
        jax.ShapeDtypeStruct((K, P), i32),       # selected route
    )
    call = pl.pallas_call(kern, out_shape=out_shape, interpret=interpret)

    def run(cts, masks, valids, nhopss, aft, lf, pf, comp, ldet, bp, lop):
        return call(aft, jnp.stack(cts), jnp.stack(masks),
                    jnp.stack(valids), jnp.stack(nhopss),
                    lf, pf, comp, ldet, bp, lop)

    run = jax.jit(run)
    _RUN_CACHE[key] = run
    return run


class PallasBackend(CandidateEvaluator):
    """Device-batched candidate evaluation: one Pallas kernel/decision."""

    name = "pallas"

    def __init__(self, inst) -> None:
        super().__init__(inst)
        self._interpret = _use_interpret()
        P = inst.P
        self._L = L = max(1, inst._n_links)
        # instance-global padded dims so per-pred tensors stack
        lays = [src_layout(inst, s) for s in range(P)]
        self._R = R = max(l.R for l in lays)
        self._H = H = max(l.H for l in lays)
        self._K = K = max([1] + [len(p) for p in inst._preds])
        self._f64 = jnp.float64
        self._src_dev: Dict[int, Tuple[jax.Array, jax.Array, jax.Array]] = {}
        self._ct_dev: Dict[Tuple[int, int, int], jax.Array] = {}
        with jax.experimental.enable_x64():
            # padding predecessor: aft = -inf, zero masks, -inf CTML, one
            # valid zero-hop route -> arrival/commit no-ops
            pad_ct = np.full((R, H, P), _NEG_INF)
            pad_valid = np.zeros((R, P))
            pad_valid[0] = 1.0
            self._pad = (jnp.asarray(pad_ct),
                         jnp.zeros((R, H, P, L), self._f64),
                         jnp.asarray(pad_valid),
                         jnp.zeros((R, P), self._f64))
            self._run = _compiled_run(K, R, H, P, L, self._interpret)

    # ------------------------------------------------------------- state
    def _alloc(self) -> None:
        inst = self.inst
        P, L = inst.P, self._L
        self.link_free = np.zeros(L, dtype=np.float64)   # host mirror
        self.proc_free = np.zeros(P, dtype=np.float64)
        self.loads = np.zeros(P, dtype=np.float64)
        self._lop = np.zeros(P, dtype=np.float64)
        self._bp = np.ones(P, dtype=np.float64)
        self._ones = np.ones(P, dtype=np.float64)
        with jax.experimental.enable_x64():
            self._lf_dev = jnp.zeros(L, dtype=self._f64)

    def apply(self, j: int, p: int, est: float, eft: float,
              msgs: list) -> None:
        super().apply(j, p, est, eft, msgs)      # host mirrors (shared code)
        lop = self.loads[p] / self.period
        self._lop[p] = lop
        self._bp[p] = 1.0 + lop * self.alpha
        if msgs:
            # scatter-commit on device: max is exact, duplicates fold in
            # commit order, so the device copy stays bit-equal to the
            # host mirror — works for fresh decisions and trace replay
            lids = [lid for (_i, _r, iv) in msgs for (lid, _s, _f) in iv]
            lfts = [f for (_i, _r, iv) in msgs for (_l, _s, f) in iv]
            with jax.experimental.enable_x64():
                self._lf_dev = self._lf_dev.at[jnp.asarray(lids)].max(
                    jnp.asarray(lfts, dtype=self._f64))

    # ----------------------------------------------------- device consts
    def _src_tensors(self, src: int):
        """One-hot hop masks + route validity/hop counts of ``src``,
        padded to the instance-global (R, H) and device-resident."""
        dev = self._src_dev.get(src)
        if dev is None:
            lay = src_layout(self.inst, src)
            P, L, R, H = lay.P, self._L, self._R, self._H
            masks = np.zeros((R, H, P, L))
            for dst in range(P):
                for r in range(lay.R):
                    for h in range(int(lay.nhops[dst, r])):
                        masks[r, h, dst, lay.lid[dst, r, h]] = 1.0
            valid = np.zeros((R, P))
            valid[:lay.R] = (~lay.invalid).T
            nhops = np.zeros((R, P))
            nhops[:lay.R] = lay.nhops.T
            with jax.experimental.enable_x64():
                dev = (jnp.asarray(masks), jnp.asarray(valid),
                       jnp.asarray(nhops))
            self._src_dev[src] = dev
        return dev

    def _edge_tensor(self, i: int, j: int, src: int, lay: SrcLayout):
        """Device CTML tensor (R, H, P) of edge ``e_ij`` from ``src``,
        shaped from the shared layout table and uploaded once."""
        ct = self._ct_dev.get((i, j, src))
        if ct is None:
            row = edge_ct(self.inst, lay, i, j)
            full = np.full((self._R, self._H, lay.P), _NEG_INF)
            if lay.R == 1:
                full[0, :lay.H] = row                # (H, P) hop-major
            else:
                full[:lay.R, :lay.H] = row.transpose(1, 2, 0)  # (P, R, H)
            with jax.experimental.enable_x64():
                ct = jnp.asarray(full)
            self._ct_dev[(i, j, src)] = ct
        return ct

    # ---------------------------------------------------------- evaluate
    def evaluate(self, j: int) -> Decision:
        inst = self.inst
        P = inst.P
        aft = self.aft
        proc_of = self.proc_of
        K = self._K

        preds = inst._preds[j]
        if len(preds) > 1:
            preds = sorted(preds, key=lambda i: (aft[i], i))
        srcs = [proc_of[i] for i in preds]
        pad_ct, pad_masks, pad_valid, pad_nhops = self._pad
        cts, masks, valids, nhopss = [], [], [], []
        aft_row = []
        for i, src in zip(preds, srcs):
            m, v, nh = self._src_tensors(src)
            cts.append(self._edge_tensor(i, j, src,
                                         inst._src_layouts[src]))
            masks.append(m)
            valids.append(v)
            nhopss.append(nh)
            aft_row.append(aft[i])
        for _ in range(K - len(preds)):
            cts.append(pad_ct)
            masks.append(pad_masks)
            valids.append(pad_valid)
            nhopss.append(pad_nhops)
            aft_row.append(_NEG_INF)

        exit_j = inst._is_exit[j]
        track = self.want_bound and not exit_j
        # exit tasks select on bare EFT (Def. 4.2): ldet = bp = 1 makes
        # the kernel's eft * ldet * bp collapse to eft exactly
        ldet_j = self._ones if exit_j else inst.ldet[j]
        bp = self._ones if exit_j else self._bp
        with jax.experimental.enable_x64():
            out = self._run(tuple(cts), tuple(masks), tuple(valids),
                            tuple(nhopss), jnp.asarray(aft_row),
                            self._lf_dev, jnp.asarray(self.proc_free),
                            jnp.asarray(inst.comp[j]), jnp.asarray(ldet_j),
                            jnp.asarray(bp), jnp.asarray(self._lop))
            win, est, eft, ca, cb, lst, lft, bestr = jax.device_get(out)
        p = int(win[0])

        msgs = []
        for k, (i, src) in enumerate(zip(preds, srcs)):
            if src == p:
                continue
            r = int(bestr[k, p])
            lids, robj = inst._src_layouts[src].route_meta[p][r]
            msgs.append((i, robj,
                         [(lids[h], float(lst[k, h, p]),
                           float(lft[k, h, p]))
                          for h in range(len(lids))]))

        if track:
            ca, cb = tuple(ca.tolist()), tuple(cb.tolist())
            contrib = self.crossing(p, ca, cb, self.alpha)
        else:
            ca = cb = None
            contrib = _INF
        return (p, float(est[p]), float(eft[p]), msgs, ca, cb, contrib)
