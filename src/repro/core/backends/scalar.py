"""Scalar candidate-evaluation backend — the bit-exactness reference.

A straight extraction of the per-processor candidate loop that used to
live inline in ``CompiledInstance._run``: flat Python lists, sequential
message-routing walks per candidate with commit/rollback of the touched
``link_free`` entries, and scalar EST/EFT/BP/selection arithmetic.  Every
floating-point operation happens in the same order as the reference
``list_schedule``, so the produced schedules are bit-identical to it —
and every other backend is held bit-identical to *this* one.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from .base import CandidateEvaluator, Decision

_INF = float("inf")


class ScalarBackend(CandidateEvaluator):
    """Per-candidate scalar loop (the PR-1 engine inner loop, verbatim)."""

    name = "scalar"

    def _alloc(self) -> None:
        inst = self.inst
        self.link_free: List[float] = [0.0] * inst._n_links
        self.proc_free: List[float] = [0.0] * inst.P
        self.loads: List[float] = [0.0] * inst.P
        self._cand_A = [0.0] * inst.P
        self._cand_B = [0.0] * inst.P

    def evaluate(self, j: int) -> Decision:
        inst = self.inst
        P = inst.P
        comp = inst._comp
        ldet = inst._ldet
        msg_plans = inst._msg_plans
        msg_plans_for = inst.msg_plans_for
        link_free = self.link_free
        proc_free = self.proc_free
        loads = self.loads
        proc_of = self.proc_of
        aft = self.aft
        alpha = self.alpha
        period = self.period
        cand_A = self._cand_A
        cand_B = self._cand_B

        order = sorted(inst._preds[j], key=lambda i: (aft[i], i))
        comp_j = comp[j]
        ldet_j = ldet[j]
        exit_j = inst._is_exit[j]
        track = self.want_bound and not exit_j
        best_value = best_eft = 0.0
        best_est = 0.0
        best_p = -1
        best_msgs: List[Tuple[int, Tuple[str, ...],
                              List[Tuple[int, float, float]]]] = []

        for p in range(P):
            arrival = 0.0
            msgs: List[Tuple[int, Tuple[str, ...],
                             List[Tuple[int, float, float]]]] = []
            touched: List[Tuple[int, float]] = []
            for i in order:
                src = proc_of[i]
                if src == p:
                    if aft[i] > arrival:
                        arrival = aft[i]
                    continue
                aft_i = aft[i]
                plans = msg_plans.get((i, j, src, p))
                if plans is None:
                    plans = msg_plans_for(i, j, src, p)      # Eq. 15
                # --- best route src -> p (Eqs. 13-15) ---
                bk0, bk1, bk2 = _INF, 0, 0
                best_iv: Optional[List[Tuple[int, float, float]]] = None
                best_route: Tuple[str, ...] = ()
                for ridx, (lids, cts, robj) in enumerate(plans):
                    iv: List[Tuple[int, float, float]] = []
                    first = True
                    lst = 0.0
                    lft = 0.0
                    for h in range(len(lids)):
                        lid = lids[h]
                        avail = link_free[lid]
                        if first:
                            lst = aft_i if aft_i > avail else avail
                            first = False
                        else:
                            lst = lst if lst > avail else avail
                        x = lst + cts[h]
                        lft = lft if lft > x else x          # Eq. 14
                        iv.append((lid, lst, lft))
                    nh = len(lids)
                    if lft < bk0 or (lft == bk0 and
                                     (nh < bk1 or (nh == bk1 and
                                                   ridx < bk2))):
                        bk0, bk1, bk2 = lft, nh, ridx
                        best_iv = iv
                        best_route = robj
                assert best_iv is not None
                for (lid, _s, f) in best_iv:
                    old = link_free[lid]
                    touched.append((lid, old))
                    if f > old:
                        link_free[lid] = f
                msgs.append((i, best_route, best_iv))
                if bk0 > arrival:
                    arrival = bk0
            pf = proc_free[p]
            est = pf if pf > arrival else arrival            # Eqs. 10-11
            eft = est + comp_j[p]                            # Eq. 12
            if exit_j:
                value = eft                                  # Def. 4.2
            else:
                bp = 1.0 + (loads[p] / period) * alpha       # Def. 4.1
                value = eft * ldet_j[p] * bp
            for lid, old in reversed(touched):
                link_free[lid] = old
            if track:
                a_p = eft * ldet_j[p]
                cand_A[p] = a_p
                cand_B[p] = a_p * (loads[p] / period)
            if best_p < 0 or value < best_value or \
                    (value == best_value and eft < best_eft):
                # strict lexicographic (value, eft, proc): p ascends,
                # so an exact (value, eft) tie keeps the earlier proc
                best_value, best_eft, best_est = value, eft, est
                best_p, best_msgs = p, msgs

        if track:
            ca, cb = tuple(cand_A), tuple(cand_B)
            contrib = self.crossing(best_p, ca, cb, alpha)
        else:
            ca = cb = None
            contrib = _INF
        return best_p, best_est, best_eft, best_msgs, ca, cb, contrib
