"""Pluggable candidate-evaluation backends for the compiled engine.

The engine's decision layer (queue walk, trace memoization, schedule
assembly) is numeric-backend agnostic; the per-task candidate evaluation
over all P processors is a :class:`CandidateEvaluator`:

  * ``"scalar"`` — :class:`ScalarBackend`, the flat-list loop extracted
    from the PR-1 engine; the bit-exactness reference.
  * ``"vector"`` — :class:`VectorBackend`, (P,)-batch NumPy array ops;
    bit-identical to scalar, faster from P >= ~8.
  * ``"pallas"`` — :class:`~.pallas.PallasBackend`, the JAX/Pallas
    device backend: whole *waves* of independent decisions (the
    engine's level batches) evaluated in one Pallas kernel launch over
    device-resident route tensors, with in-kernel winner commits to
    persistent device link/processor state — one host round-trip per
    wave, O(levels) per schedule (interpret mode on CPU-only hosts,
    f32 + tile-padded for a Mosaic compile on TPU).  Opt-in —
    ``"auto"`` never selects it — and imported lazily so the NumPy
    backends work without jax installed.
  * ``"auto"``  — resolves per instance: vector when ``P >= 8`` and the
    topology is vector-compatible, scalar otherwise.

The environment variable ``REPRO_SCHED_BACKEND`` overrides the *default*
(used when a caller passes ``backend=None``); explicit ``backend=``
arguments always win.  CI runs the tier-1 suite under all three backends
via this variable.

Backend/topology compatibility is validated *at resolve time*: an
explicit ``backend="vector"`` on a topology whose routes revisit a link
raises :class:`BackendCompatError` before any session state (plan/trace
caches, compiled instances) is touched, not mid-``submit``.

Adding a backend is one file: subclass :class:`CandidateEvaluator`,
implement ``_alloc``/``evaluate`` (and optionally override
``evaluate_batch`` to fuse a whole decision wave, as pallas does — the
sequential default keeps scalar/vector bit-exact), and register the
class here — policy
code, the session API, traces, and the benchmarks pick it up through the
``backend=`` string.  The shared route-tensor layout precompute lives in
:mod:`.layout` (built once per instance, reused by every array backend).
"""
from __future__ import annotations

import importlib.util
import os
from typing import Dict, List, Optional, Type, TYPE_CHECKING

from .base import BackendCompatError, CandidateEvaluator, Decision

if TYPE_CHECKING:                                   # pragma: no cover
    from ..topology import Topology
from .scalar import ScalarBackend
from .vector import VectorBackend

__all__ = [
    "CandidateEvaluator", "Decision", "ScalarBackend", "VectorBackend",
    "BackendCompatError", "BACKENDS", "AUTO_VECTOR_MIN_P", "PALLAS",
    "available_backends", "backend_class", "default_backend",
    "resolve_backend_name", "vector_compatible",
]

BACKENDS: Dict[str, Type[CandidateEvaluator]] = {
    ScalarBackend.name: ScalarBackend,
    VectorBackend.name: VectorBackend,
}

# The device backend is registered lazily on first use: importing it
# pulls in jax, which must stay optional for the NumPy-only install.
PALLAS = "pallas"

# "auto" switches to the batched backend where the (P,)-vector ops
# amortize their per-call overhead (measured in benchmarks/exp7).
AUTO_VECTOR_MIN_P = 8

_ENV_VAR = "REPRO_SCHED_BACKEND"


def _pallas_available() -> bool:
    return importlib.util.find_spec("jax") is not None


def available_backends() -> List[str]:
    names = set(BACKENDS)
    if _pallas_available():
        names.add(PALLAS)
    return sorted(names)


def backend_class(name: str) -> Type[CandidateEvaluator]:
    """The evaluator class for a *resolved* backend name (lazy-imports
    the Pallas backend on first use)."""
    cls = BACKENDS.get(name)
    if cls is None and name == PALLAS:
        from .pallas import PallasBackend     # deferred jax import
        BACKENDS[PALLAS] = cls = PallasBackend
    if cls is None:
        raise ValueError(f"unknown backend {name!r}; available: "
                         f"{available_backends()} or 'auto'")
    return cls


def default_backend() -> str:
    """The session default: ``REPRO_SCHED_BACKEND`` or ``"auto"``."""
    return os.environ.get(_ENV_VAR, "auto")


def vector_compatible(tg: "Topology") -> bool:
    """Vector batching needs link-disjoint routes (see VectorBackend).

    Pure function of the (frozen-by-convention) route tables, memoized
    on the topology: auto-resolution runs per submit/update and must not
    re-scan O(routes) each time.
    """
    ok = getattr(tg, "_vector_compat", None)
    if ok is None:
        ok = all(len(set(r)) == len(r)
                 for rr in tg.routes.values() for r in rr)
        tg._vector_compat = ok
    return ok


def resolve_backend_name(backend: Optional[str], P: int,
                         tg: "Topology") -> str:
    """Resolve a requested backend to a concrete registered name.

    ``None`` means "the default" (env override or auto); ``"auto"``
    picks vector for ``P >= AUTO_VECTOR_MIN_P`` on vector-compatible
    topologies (never pallas — the device backend is opt-in).  Explicit
    names are validated here, *before* any session state is built: an
    unknown name raises ``ValueError``, and an explicit ``"vector"`` on
    a link-reuse topology raises :class:`BackendCompatError` at resolve
    time so the caller's plan/trace caches are never keyed for a plan
    that cannot materialize.
    """
    if backend is None:
        backend = default_backend()
    if backend == "auto":
        if P >= AUTO_VECTOR_MIN_P and vector_compatible(tg):
            return VectorBackend.name
        return ScalarBackend.name
    if backend not in BACKENDS and backend != PALLAS:
        raise ValueError(f"unknown backend {backend!r}; available: "
                         f"{available_backends()} or 'auto'")
    if backend == VectorBackend.name and not vector_compatible(tg):
        raise BackendCompatError(
            "a route of this topology visits a link twice; the vector "
            "backend's batched scatter needs link-disjoint routes — "
            "use backend='scalar'")
    if backend == PALLAS and PALLAS not in BACKENDS:
        if not _pallas_available():
            raise ValueError("backend='pallas' requires jax (pip install "
                             "\"jax[cpu]\"); use backend='vector' or "
                             "'scalar' on jax-free installs")
        # Import (and register) the device backend NOW: an explicit
        # pallas request will import jax anyway, and an importable-but-
        # broken install (jaxlib mismatch) must fail at resolve time —
        # before any session/plan-cache state exists — like every other
        # invalid backend request, not mid-submit.
        try:
            backend_class(PALLAS)
        except Exception as e:
            raise ValueError(
                "backend='pallas' requires a working jax install "
                f"(pip install \"jax[cpu]\"): importing it failed with "
                f"{type(e).__name__}: {e}") from e
    return backend
