"""Pluggable candidate-evaluation backends for the compiled engine.

The engine's decision layer (queue walk, trace memoization, schedule
assembly) is numeric-backend agnostic; the per-task candidate evaluation
over all P processors is a :class:`CandidateEvaluator`:

  * ``"scalar"`` — :class:`ScalarBackend`, the flat-list loop extracted
    from the PR-1 engine; the bit-exactness reference.
  * ``"vector"`` — :class:`VectorBackend`, (P,)-batch NumPy array ops;
    bit-identical to scalar, faster from P >= ~8.
  * ``"auto"``  — resolves per instance: vector when ``P >= 8`` and the
    topology is vector-compatible, scalar otherwise.

The environment variable ``REPRO_SCHED_BACKEND`` overrides the *default*
(used when a caller passes ``backend=None``); explicit ``backend=``
arguments always win.  CI runs the tier-1 suite under both backends via
this variable.

Adding a backend is one file: subclass :class:`CandidateEvaluator`,
implement ``_alloc``/``evaluate``, and register the class here — policy
code, the session API, traces, and the benchmarks pick it up through the
``backend=`` string.  This is the extension point for an accelerator
(JAX/Pallas) batch backend.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Type

from .base import CandidateEvaluator, Decision
from .scalar import ScalarBackend
from .vector import BackendCompatError, VectorBackend

__all__ = [
    "CandidateEvaluator", "Decision", "ScalarBackend", "VectorBackend",
    "BackendCompatError", "BACKENDS", "AUTO_VECTOR_MIN_P",
    "available_backends", "default_backend", "resolve_backend_name",
    "vector_compatible",
]

BACKENDS: Dict[str, Type[CandidateEvaluator]] = {
    ScalarBackend.name: ScalarBackend,
    VectorBackend.name: VectorBackend,
}

# "auto" switches to the batched backend where the (P,)-vector ops
# amortize their per-call overhead (measured in benchmarks/exp7).
AUTO_VECTOR_MIN_P = 8

_ENV_VAR = "REPRO_SCHED_BACKEND"


def available_backends() -> list:
    return sorted(BACKENDS)


def default_backend() -> str:
    """The session default: ``REPRO_SCHED_BACKEND`` or ``"auto"``."""
    return os.environ.get(_ENV_VAR, "auto")


def vector_compatible(tg) -> bool:
    """Vector batching needs link-disjoint routes (see VectorBackend).

    Pure function of the (frozen-by-convention) route tables, memoized
    on the topology: auto-resolution runs per submit/update and must not
    re-scan O(routes) each time.
    """
    ok = getattr(tg, "_vector_compat", None)
    if ok is None:
        ok = all(len(set(r)) == len(r)
                 for rr in tg.routes.values() for r in rr)
        tg._vector_compat = ok
    return ok


def resolve_backend_name(backend: Optional[str], P: int, tg) -> str:
    """Resolve a requested backend to a concrete registered name.

    ``None`` means "the default" (env override or auto); ``"auto"``
    picks vector for ``P >= AUTO_VECTOR_MIN_P`` on vector-compatible
    topologies.  Explicit names are validated (an explicit ``"vector"``
    on an incompatible topology raises when the backend is built).
    """
    if backend is None:
        backend = default_backend()
    if backend == "auto":
        if P >= AUTO_VECTOR_MIN_P and vector_compatible(tg):
            return VectorBackend.name
        return ScalarBackend.name
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; available: "
                         f"{available_backends()} or 'auto'")
    return backend
