"""Candidate-evaluation backend protocol (the numeric layer of the engine).

:class:`~repro.core.engine.CompiledInstance` is split in two:

  * the **decision layer** (``engine._run``) owns the priority-queue walk,
    precedence checks, decision-trace recording/resume, and `Schedule`
    assembly — pure Python, identical for every backend;
  * the **numeric layer** (a :class:`CandidateEvaluator`) owns the
    per-task candidate evaluation over all ``P`` processors — the
    sequential message-routing walks (Eqs. 13-15), the batched EST/EFT
    (Eqs. 10-12), the BP load-balance term (Def. 4.1), the selection
    value (Def. 4.2), winner selection, and the alpha crossing bound.

A backend owns the mutable run state: ``link_free`` (flat, link-id
indexed — a Python list for the scalar backend, a ``(L,)`` ndarray for
the vector backend), ``proc_free``, ``loads``, and the per-task
``proc_of``/``ast``/``aft`` outputs.  Committing a decision
(:meth:`apply`) is *shared* scalar code: a handful of per-hop max
updates, identical floats in identical order no matter which backend
produced the decision.  That is what makes decision traces portable — a
trace recorded under one backend replays bit-identically under another.

Invariant: every backend performs the same IEEE-754 operations as the
reference ``list_schedule`` (reassociating only *exact* operations such
as ``max``), so all backends are mutually **bit-identical**
(``tests/test_backend_equivalence.py``).
"""
from __future__ import annotations

import abc
import time
from typing import ClassVar, List, Optional, Sequence, Tuple, TYPE_CHECKING

from ..faults import WaveTimeoutError

if TYPE_CHECKING:                                   # pragma: no cover
    from ..engine import CompiledInstance

__all__ = ["BackendCompatError", "CandidateEvaluator", "Decision"]

_INF = float("inf")


class BackendCompatError(ValueError):
    """The instance's topology cannot be expressed by this backend.

    Raised eagerly by :func:`~..backends.resolve_backend_name` when an
    explicit backend request is incompatible with the topology (so no
    session/plan cache is ever keyed for a plan that cannot be built),
    and defensively by backend constructors.
    """


# What `evaluate` returns: the DecisionRecord tail plus the decision's
# alpha crossing-bound contribution (inf when not tracking):
#   (proc, est, eft, msgs, cand_A, cand_B, bound_contrib)
# with ``msgs`` = [(pred, route, [(link_id, lst, lft), ...]), ...].
Decision = Tuple[int, float, float, list, Optional[tuple], Optional[tuple],
                 float]


class CandidateEvaluator(abc.ABC):
    """One candidate-evaluation backend bound to one compiled instance.

    Lifecycle per ``_run``: ``start(alpha, period, want_bound)`` resets
    the run state, then for every dequeued task either
    ``evaluate(j)`` + ``apply(rec)`` (full candidate loop) or
    ``apply(rec)`` alone (trace replay of a memoized decision).
    """

    name: ClassVar[str]

    def __init__(self, inst: "CompiledInstance") -> None:
        self.inst = inst

    # -------------------------------------------------------------- run
    def start(self, alpha: float, period: float, want_bound: bool) -> None:
        inst = self.inst
        self.alpha = alpha
        self.period = period
        self.want_bound = want_bound
        self.proc_of: List[int] = [-1] * inst.n
        self.ast: List[float] = [0.0] * inst.n
        self.aft: List[float] = [0.0] * inst.n
        self._alloc()

    @abc.abstractmethod
    def _alloc(self) -> None:
        """Allocate/reset ``link_free``, ``proc_free``, ``loads`` in the
        backend's preferred container (list vs ndarray)."""

    @abc.abstractmethod
    def evaluate(self, j: int) -> Decision:
        """Evaluate all P placement candidates for task ``j`` against the
        current run state and pick the winner (Eqs. 10-15, Defs. 4.1-4.2).
        Does NOT mutate run state — the caller commits via :meth:`apply`.
        """

    def evaluate_batch(self, js: Sequence[int]) -> List[Decision]:
        """Evaluate-and-commit a batch of *independent* tasks, in order.

        The engine's decision layer groups consecutive same-rank-level
        queue entries (no precedence edges inside a batch — every
        predecessor is already committed) and hands the whole wave to the
        backend.  Decisions inside a batch still interact through the
        shared link/processor state, so they are evaluated and committed
        **sequentially**; batching changes where the loop runs, never the
        decisions.

        This default runs the per-decision path verbatim — ``evaluate``
        then :meth:`apply` per task, the exact op order of the unbatched
        engine — so the scalar/vector backends stay bit-exact and their
        traces trace-portable by construction.  A device backend
        overrides this to evaluate the whole batch in one kernel launch
        with in-kernel commits (see ``backends/pallas.py``), returning
        the same per-task :data:`Decision` tuples.

        Contract: run state after ``evaluate_batch(js)`` equals the
        state after ``for j in js: apply(j, *evaluate(j)[:3], ...)`` up
        to the backend's precision contract, and the returned decisions
        are in ``js`` order.
        """
        decisions: List[Decision] = []
        for j in js:
            d = self.evaluate(j)
            self.apply(j, d[0], d[1], d[2], d[3])
            decisions.append(d)
        return decisions

    def evaluate_plan(self, waves: Sequence[Sequence[int]],
                      timeout: Optional[float] = None,
                      bid0: int = 0) -> List[List[Decision]]:
        """Evaluate-and-commit a whole **wave plan** (the full schedule).

        The engine's decision layer now emits the complete level-batched
        wave plan up front (:func:`~..engine.plan_waves` — a pure
        function of the queue and the precedence edges) and hands it to
        the backend in one call.  This sequential default walks the plan
        wave by wave through :meth:`evaluate_batch` — the exact op order
        of the interleaved engine loop it replaced, so the scalar/vector
        backends stay bit-exact by construction.  A device backend
        overrides this to run the *entire* plan in a single dispatch
        (the Pallas ``lax.scan`` path) and decode one fetch.

        ``timeout`` is the engine's per-wave watchdog budget: the
        default raises :class:`~..faults.WaveTimeoutError` when one
        ``evaluate_batch`` overruns it (``bid0 + k`` names the offending
        wave's batch id); a whole-plan backend compares its single
        dispatch against ``timeout * len(waves)``.

        Contract: returns one decision list per wave, ``waves[k]``
        order; run state afterwards equals the sequential walk's.
        """
        out: List[List[Decision]] = []
        for k, wave in enumerate(waves):
            if timeout is None:
                out.append(self.evaluate_batch(wave))
            else:
                t0 = time.monotonic()
                out.append(self.evaluate_batch(wave))
                elapsed = time.monotonic() - t0
                if elapsed > timeout:
                    raise WaveTimeoutError(bid0 + k, elapsed, timeout)
        return out

    # ------------------------------------------------------- fused sweep
    def supports_plan_sweep(self) -> bool:
        """Whether :meth:`evaluate_plan_sweep` evaluates a whole alpha
        grid in one dispatch.  Default: no — the session API keeps the
        (trace-invariance-pruned) host-side per-alpha loop."""
        return False

    def evaluate_plan_sweep(self, waves: Sequence[Sequence[int]],
                            alphas: Sequence[float], period: float,
                            timeout: Optional[float] = None
                            ) -> List[List[List[Decision]]]:
        """Evaluate one wave plan under *every* alpha of a sweep grid in
        a single dispatch (the (A, B) fused launch, DESIGN.md §5).

        Returns ``[alpha][wave] -> decisions`` with per-alpha decisions
        identical to ``len(alphas)`` independent :meth:`evaluate_plan`
        runs.  Decodes with bound tracking (``cand_A``/``cand_B``
        populated) so the recorded traces resume exactly like host-loop
        sweep traces.  Must NOT commit to the backend's run state — the
        per-alpha runs are independent; callers re-``start()`` before
        reusing the instance.  Only called when
        :meth:`supports_plan_sweep` is true.
        """
        raise NotImplementedError(
            f"backend {self.name!r} does not fuse alpha sweeps")

    # ------------------------------------------------------------ commit
    def apply(self, j: int, p: int, est: float, eft: float,
              msgs: list) -> None:
        """Commit one decision (fresh or replayed from a trace).

        Scalar on purpose: a committed decision touches only the winner's
        row — a few floats — and sharing this code across backends is
        what guarantees a trace replays bit-identically anywhere.
        """
        self.proc_of[j] = p
        self.ast[j] = est
        self.aft[j] = eft
        self.proc_free[p] = eft
        self.loads[p] += self.inst._comp[j][p]
        link_free = self.link_free
        for (_i, _route, iv) in msgs:
            for (lid, _s, f) in iv:
                if f > link_free[lid]:
                    link_free[lid] = f

    # ------------------------------------------------------------- bound
    @staticmethod
    def crossing(p: int, cand_A: Sequence[float], cand_B: Sequence[float],
                 alpha: float) -> float:
        """Supremum-alpha contribution of one decision (see DESIGN §3).

        For winner ``p`` with per-candidate linear selection values
        ``A_r + B_r * a``, returns the smallest rival crossing point
        ``(A_r - A_p) / (B_p - B_r)`` — or ``alpha`` itself when a rival
        is numerically indistinguishable — or ``inf`` when the winner
        keeps winning forever.  Shared reference implementation used for
        trace replay; backends may vectorize the live path as long as
        they produce the identical float.
        """
        bound = _INF
        a_c, b_c = cand_A[p], cand_B[p]
        n = len(cand_A)
        for r in range(n):
            if r == p:
                continue
            d_b = b_c - cand_B[r]
            d_a = cand_A[r] - a_c
            scale = abs(a_c) + abs(cand_A[r]) + 1.0
            if d_b > 1e-15 * scale:
                a_star = d_a / d_b
                if a_star < bound:
                    bound = a_star
            elif abs(d_b) <= 1e-15 * scale and abs(d_a) <= 1e-12 * scale:
                if alpha < bound:
                    bound = alpha
        return bound
