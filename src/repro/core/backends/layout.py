"""Shared route-tensor layout precompute for the array backends.

The batched backends (NumPy :class:`~.vector.VectorBackend`, Pallas
:class:`~.pallas.PallasBackend`) evaluate all P placement candidates of
one dequeued task at once, which requires the topology's route tables in
tensor form: per hop, a ``(P,)`` row of link ids / gather indices /
speeds per destination lane.  Those tensors are a pure function of
``(topology, source processor)`` — the message *edge* only contributes a
scalar volume ``tpl(e_ij | src)`` that scales the per-hop CTML row — so
they are built **once per (instance, src)** here and shared by

  * every edge whose source task sits on ``src`` (the vector backend
    used to rebuild them per ``(edge, src)``, which made a cold submit
    cost ~2x a warm pass at n = 500 — the per-edge work is now one
    vectorized CTML fill over the shared layout), and
  * every backend bound to the same :class:`~..engine.CompiledInstance`
    (the cache lives on the instance, not the backend).

Bit-exactness: :func:`edge_ct` performs the same IEEE-754 operations as
the scalar ``CompiledInstance.msg_plans_for`` path — one ``tpl / speed``
division per hop plus the Eq. 15 quantization (``round`` is IEEE
round-half-even in both ``float(round(t))`` and ``np.rint``; ``ceil``
likewise) — elementwise over the layout tensors, so the produced CTML
floats equal the scalar plan cache's bit for bit
(``tests/test_backend_equivalence.py`` holds all backends to it).
"""
from __future__ import annotations

from typing import List, Optional, Tuple, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                                   # pragma: no cover
    from ..engine import CompiledInstance

__all__ = ["LANE", "SUBLANE_F32", "SrcLayout", "edge_ct", "ensure_ct_table",
           "pad_dim", "padded_edge_ct", "padded_src_tensors", "src_layout",
           "stacked_edge_ct", "stacked_src_tensors"]

_NEG_INF = float("-inf")


class SrcLayout:
    """Padded route tensors of one source processor against a topology.

    Hop tensors are ``(P, R, H)`` — destination lane x route x hop —
    where ``R``/``H`` are the maximum route count / hop count over all
    destinations for this source.  Padding conventions (shared contract
    of every array backend):

      * hop padding (``pad``): no real link — reads must see ``-inf``
        and the CTML must be ``-inf`` so both Eq. 13/14 running maxima
        are no-ops;
      * route padding (``invalid``): masked to ``+inf`` arrival so it
        never wins the (LFT, hops, index) route selection;
      * the ``src`` destination lane owns a fake zero-CTML route 0 whose
        final LFT is exactly ``aft_i`` — the scalar path's
        same-processor arrival contribution — so no post-hoc masking.

    ``read_idx``/``base_idx``/``write_idx`` (and the contiguous
    ``av_idx``/``base_flat``/``w_rows`` forms used by the vector
    backend's single-route fast path) address the vector backend's flat
    ``(P*L + 2,)`` lane buffer: slot ``P*L`` is the write-only sink,
    slot ``P*L + 1`` the read-only ``-inf``; the committed ``(L + 1,)``
    link state uses slot ``L`` as its ``-inf``.
    """

    __slots__ = ("src", "P", "L", "R", "H", "lid", "spd", "pad",
                 "nhops", "invalid", "has_invalid", "route_meta",
                 "read_idx", "base_idx", "write_idx",
                 "av_idx", "base_flat", "w_rows",
                 "spd_rows", "pad_flat", "ct_table")

    def __init__(self, inst: "CompiledInstance", src: int) -> None:
        P = inst.P
        L = inst._n_links
        self.src, self.P, self.L = src, P, L
        routes = inst._routes
        R = H = 1
        route_meta: List[List[Tuple[Tuple[int, ...], Tuple[str, ...]]]] = []
        for dst in range(P):
            if dst == src:
                route_meta.append([])
                continue
            rr = routes[(src, dst)]
            meta = []
            for (lids, _spds, robj) in rr:
                meta.append((lids, robj))
                H = max(H, len(lids))
            R = max(R, len(rr))
            route_meta.append(meta)
        self.R, self.H = R, H
        self.route_meta = route_meta

        sink = P * L
        neg = P * L + 1
        lid = np.full((P, R, H), L, dtype=np.intp)      # L = virtual pad link
        spd = np.ones((P, R, H), dtype=np.float64)
        pad = np.ones((P, R, H), dtype=bool)
        read_idx = np.full((P, R, H), neg, dtype=np.intp)
        base_idx = np.full((P, R, H), L, dtype=np.intp)  # L = -inf slot
        write_idx = np.full((P, R, H), sink, dtype=np.intp)
        nhops = np.zeros((P, R), dtype=np.int64)
        invalid = np.ones((P, R), dtype=bool)
        for dst in range(P):
            if dst == src:
                invalid[dst, 0] = False      # fake zero-CTML route
                continue
            for r, (lids, spds, _robj) in enumerate(routes[(src, dst)]):
                invalid[dst, r] = False
                nhops[dst, r] = len(lids)
                for h, l in enumerate(lids):
                    lid[dst, r, h] = l
                    spd[dst, r, h] = spds[h]
                    pad[dst, r, h] = False
                    read_idx[dst, r, h] = dst * L + l
                    base_idx[dst, r, h] = l
                    write_idx[dst, r, h] = dst * L + l
        self.lid, self.spd, self.pad = lid, spd, pad
        self.nhops, self.invalid = nhops, invalid
        self.has_invalid = bool(invalid.any())
        self.read_idx, self.base_idx, self.write_idx = (read_idx, base_idx,
                                                        write_idx)
        # contiguous single-route forms (hop-major) for the R == 1 path
        self.av_idx = np.ascontiguousarray(read_idx[:, 0, :].T).ravel()
        self.base_flat = np.ascontiguousarray(base_idx[:, 0, :].T).ravel()
        self.w_rows = [np.ascontiguousarray(write_idx[:, 0, h])
                       for h in range(H)]
        # per-edge CTML fill helpers (edge_ct): hop-major speeds for the
        # single-route path, flat pad indices for either shape
        self.spd_rows: Optional[np.ndarray]
        if R == 1:
            self.spd_rows = np.ascontiguousarray(spd[:, 0, :].T)  # (H, P)
            self.pad_flat = np.flatnonzero(pad[:, 0, :].T.ravel())
        else:
            self.spd_rows = None
            self.pad_flat = np.flatnonzero(pad.ravel())
        # all-edge CTML table, built lazily
        self.ct_table: Optional[np.ndarray] = None


def src_layout(inst: "CompiledInstance", src: int) -> SrcLayout:
    """The (cached) :class:`SrcLayout` of ``src`` for one instance.

    The cache lives on the :class:`~..engine.CompiledInstance`
    (``inst._src_layouts``) so every backend bound to the instance —
    and every edge — shares one build.
    """
    lay = inst._src_layouts.get(src)
    if lay is None:
        lay = SrcLayout(inst, src)
        inst._src_layouts[src] = lay
    return lay


def ensure_ct_table(inst: "CompiledInstance", lay: SrcLayout) -> np.ndarray:
    """Eq. 15 CTML tensors of *every* edge from ``lay.src``, in one shot.

    Route-tensor precompilation: the first decision that places a task
    on ``src`` pays one vectorized ``(E, ...)`` division + quantization
    over all E graph edges, and every later edge evaluated from ``src``
    is a table row view — so a cold submit does per-*src* work (P of
    them), not per-(edge, src) work (O(E * P) of them).

    Identical floats to the scalar ``msg_plans_for`` path: ``tpl /
    speed`` is one IEEE division either way, ``np.rint``/``np.ceil``
    match ``float(round(t))`` / ``float(np.ceil(t))`` elementwise.

    Row shape follows the backend fast paths: hop-major ``(H, P)`` for
    single-route layouts, the full ``(P, R, H)`` tensor otherwise.
    ~``E * P * R * H`` doubles per source processor — a few MB at the
    exp7 n=500 scale.
    """
    t = inst._tpl_matrix[:, lay.src]                         # (E,)
    single = lay.R == 1
    if single:
        ct = t[:, None, None] / lay.spd_rows                 # (E, H, P)
    else:
        ct = t[:, None, None, None] / lay.spd                # (E, P, R, H)
    mode = inst._ctml_mode
    if mode == "round":
        np.rint(ct, out=ct)
    elif mode == "ceil":
        np.ceil(ct, out=ct)
    ct.reshape(len(t), -1)[:, lay.pad_flat] = _NEG_INF
    if single:
        ct[:, :, lay.src] = 0.0      # fake route: final LFT == aft_i
    else:
        ct[:, lay.src, 0, :] = 0.0
    lay.ct_table = ct
    return ct


def edge_ct(inst: "CompiledInstance", lay: SrcLayout,
            i: int, j: int) -> np.ndarray:
    """CTML tensor of edge ``e_ij`` from ``lay.src`` — a row view of the
    precompiled all-edge table (see :func:`ensure_ct_table`)."""
    tab = lay.ct_table
    if tab is None:
        tab = ensure_ct_table(inst, lay)
    return tab[inst._edge_index[(i, j)]]


# ----------------------------------------------------------------------
# Tile-padded variants for the device backend
# ----------------------------------------------------------------------
# TPU vector registers are (sublane, lane) tiles; for float32 the minimum
# tile is (8, 128).  The Pallas backend's dominant 2-D arrays put the
# candidate-processor axis on sublanes and the link axis on lanes (the
# (P, L) lane buffer and the per-hop one-hot masks), so a Mosaic-compiled
# kernel wants P padded to a sublane multiple and L to a lane multiple.
# Padding is arithmetic, not control flow (same contract as the hop/route
# padding above): padded processor lanes carry +inf computation cost and
# all-invalid routes, so they never win a selection and never commit;
# padded links are never masked in, so they are never read or written.
LANE = 128          # last-dim tile multiple (all dtypes)
SUBLANE_F32 = 8     # second-to-last-dim tile multiple for float32


def pad_dim(x: int, multiple: int) -> int:
    """``x`` rounded up to a multiple (identity when ``multiple`` is 1)."""
    return -(-x // multiple) * multiple


def padded_src_tensors(inst: "CompiledInstance", src: int, R: int, H: int,
                       Pp: int, Lp: int) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray]:
    """Route tensors of ``src`` padded to instance-global device dims.

    Returns ``(masks, valid, nhops)`` as float64 NumPy arrays (the device
    backend casts to its kernel dtype on upload):

      * ``masks``  — ``(R, H, Pp, Lp)`` one-hot hop masks over the link
        axis (zero rows for hop/route/lane padding and for the
        ``dst == src`` fake route, which owns no links),
      * ``valid``  — ``(R, Pp)`` route validity (0 for route padding and
        for every tile-padded processor lane),
      * ``nhops``  — ``(R, Pp)`` per-route hop counts.

    ``R``/``H`` are the instance-global maxima over all sources (so one
    compiled kernel serves every decision); ``Pp``/``Lp`` are the
    processor/link counts, tile-padded via :func:`pad_dim` when the
    backend targets a real Mosaic compile.
    """
    lay = src_layout(inst, src)
    P = lay.P
    masks = np.zeros((R, H, Pp, Lp))
    for dst in range(P):
        for r in range(lay.R):
            for h in range(int(lay.nhops[dst, r])):
                masks[r, h, dst, lay.lid[dst, r, h]] = 1.0
    valid = np.zeros((R, Pp))
    valid[:lay.R, :P] = (~lay.invalid).T
    nhops = np.zeros((R, Pp))
    nhops[:lay.R, :P] = lay.nhops.T
    return masks, valid, nhops


def stacked_src_tensors(inst: "CompiledInstance", R: int, H: int,
                        Pp: int, Lp: int) -> Tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    """Route tensors of **every** source processor, stacked on a leading
    src axis, for the device-resident scan path (DESIGN.md §5).

    The whole-schedule ``lax.scan`` cannot stage per-predecessor tensors
    on the host (a predecessor's placement is decided *inside* the scan),
    so the backend uploads the full ``(P + 1, ...)`` stack once and the
    scan body gathers row ``proc_of[pred]`` dynamically.  Row ``P`` is
    the **padding predecessor** plane — all-zero masks, one valid
    zero-hop route per lane — mirroring the per-wave path's pad tensors:
    a padded slot's arrival/commit contributions drop out of the exact
    max algebra.

    Returns ``(masks, valid, nhops)`` shaped ``(P + 1, R, H, Pp, Lp)`` /
    ``(P + 1, R, Pp)`` / ``(P + 1, R, Pp)`` (float64; the backend casts
    on upload).
    """
    P = inst.P
    masks = np.zeros((P + 1, R, H, Pp, Lp))
    valid = np.zeros((P + 1, R, Pp))
    nhops = np.zeros((P + 1, R, Pp))
    for s in range(P):
        masks[s], valid[s], nhops[s] = padded_src_tensors(
            inst, s, R, H, Pp, Lp)
    valid[P, 0, :] = 1.0             # pad src: fake zero-hop route 0
    return masks, valid, nhops


def stacked_edge_ct(inst: "CompiledInstance", R: int, H: int, Pp: int,
                    Ep: int) -> np.ndarray:
    """Eq. 15 CTML of **every** edge from **every** source, stacked to
    ``(Ep, P + 1, R, H, Pp)`` for the scan path's dynamic double gather
    ``ct[edge_index, proc_of[pred]]``.

    ``Ep >= E + 1``: rows ``>= E`` and source plane ``P`` are the
    padding-predecessor convention (``-inf`` everywhere — a no-op of the
    Eq. 13-14 max algebra; the pad source's fake route 0 is validated in
    :func:`stacked_src_tensors`).  Built from the per-src all-edge
    tables (:func:`ensure_ct_table`), so the floats are bit-identical to
    the per-wave path's :func:`padded_edge_ct` views.
    """
    E = len(inst._edge_index)
    assert Ep >= E + 1
    full = np.full((Ep, inst.P + 1, R, H, Pp), _NEG_INF)
    for s in range(inst.P):
        lay = src_layout(inst, s)
        if E == 0:
            continue
        tab = lay.ct_table
        if tab is None:
            tab = ensure_ct_table(inst, lay)
        if lay.R == 1:
            full[:E, s, 0, :lay.H, :lay.P] = tab         # (E, H, P)
        else:
            full[:E, s, :lay.R, :lay.H, :lay.P] = \
                tab.transpose(0, 2, 3, 1)                # (E, P, R, H)
    return full


def padded_edge_ct(inst: "CompiledInstance", lay: SrcLayout, i: int, j: int,
                   R: int, H: int, Pp: int) -> np.ndarray:
    """CTML tensor of edge ``e_ij`` from ``lay.src`` padded to the
    instance-global ``(R, H, Pp)`` device shape: hop/route/lane padding
    reads ``-inf`` (a no-op of the Eq. 13-14 max algebra; padded lanes
    are additionally masked invalid in :func:`padded_src_tensors`)."""
    row = edge_ct(inst, lay, i, j)
    full = np.full((R, H, Pp), _NEG_INF)
    if lay.R == 1:
        full[0, :lay.H, :lay.P] = row                    # (H, P) hop-major
    else:
        full[:lay.R, :lay.H, :lay.P] = row.transpose(1, 2, 0)  # (P, R, H)
    return full
