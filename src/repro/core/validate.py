"""Independent schedule validation + session-boundary input checks.

:func:`schedule_violations` re-derives every structural invariant of a
:class:`~.scheduler.Schedule` from the placements and message intervals
alone — deliberately *not* reusing the engine's own bookkeeping
(``Schedule.validate`` asserts from inside the producing code path; this
module is the oracle the chaos harness judges it by):

  * **precedence** — a same-processor successor starts at/after its
    predecessor's finish; a cross-processor successor starts at/after
    the final hop LFT of its message, whose first hop starts at/after
    the predecessor's finish (Eqs. 10-14);
  * **processor exclusivity** — tasks sharing a processor never overlap;
  * **link-contention exclusivity** — message occupancy intervals
    sharing a link never overlap (Section 2.3's contended network);
  * **route feasibility** — every message travels a route the topology
    actually defines between its endpoint processors, hop links in
    route order;
  * **duration** — every task occupies exactly ``comp(task, proc)``;
  * **fault avoidance** (with a :class:`~.faults.FaultSpec`) — nothing
    is placed on a down processor, no message occupies a down link.

The ``check_*`` helpers are the actionable input validation used at the
:class:`~.api.Scheduler` session boundary (reject NaN/zero/negative
rates and speeds, unknown task ids, malformed graphs) so bad input
fails with a one-line ``ValueError`` instead of a deep engine/NumPy
stack trace.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .faults import FaultSpec
from .graph import SPG
from .scheduler import Schedule
from .topology import Topology

# Comparison slack for re-derived invariants: engine floats are exact
# (every commit is plain IEEE arithmetic), but the duration check
# re-multiplies weight x rate, so allow a few ulps of headroom.
_EPS = 1e-9


class ScheduleValidationError(ValueError):
    """A schedule violated an independent structural invariant."""

    def __init__(self, violations: List[str]) -> None:
        self.violations = violations
        super().__init__(
            f"{len(violations)} schedule violation(s):\n  " +
            "\n  ".join(violations))


def schedule_violations(s: Schedule,
                        spec: Optional[FaultSpec] = None) -> List[str]:
    """Every invariant violation of ``s`` (empty list == valid)."""
    g, tg = s.graph, s.topology
    out: List[str] = []
    horizon = float(max(s.finish.max(), 1.0)) if g.n else 1.0
    tol = _EPS * horizon
    down_links = set(spec.down_links) if spec is not None else set()
    down_procs = set(spec.down_procs) if spec is not None else set()

    # --- task placement / duration / fault avoidance ---
    for t in range(g.n):
        p = int(s.proc[t])
        if not 0 <= p < tg.n_procs:
            out.append(f"task {t}: placed on invalid processor {p}")
            continue
        if p in down_procs:
            out.append(f"task {t}: placed on down processor {p}")
        st, fi = float(s.start[t]), float(s.finish[t])
        if not (math.isfinite(st) and math.isfinite(fi)) or fi < st:
            out.append(f"task {t}: malformed interval [{st}, {fi}]")
            continue
        comp = g.comp(t, p, tg.rates)
        if abs((fi - st) - comp) > tol + _EPS * abs(comp):
            out.append(f"task {t}: duration {fi - st:.9g} != "
                       f"comp(t, p{p}) = {comp:.9g}")

    # --- processor exclusivity ---
    by_proc: Dict[int, List[int]] = {}
    for t in range(g.n):
        by_proc.setdefault(int(s.proc[t]), []).append(t)
    for p, tasks in by_proc.items():
        tasks.sort(key=lambda t: (float(s.start[t]), float(s.finish[t])))
        for a, b in zip(tasks, tasks[1:]):
            if float(s.finish[a]) > float(s.start[b]) + tol:
                out.append(f"processor {p}: tasks {a} and {b} overlap "
                           f"([{s.start[a]:.6g}, {s.finish[a]:.6g}] vs "
                           f"[{s.start[b]:.6g}, {s.finish[b]:.6g}])")

    # --- precedence + per-message structure ---
    for (i, j) in g.edges:
        pi, pj = int(s.proc[i]), int(s.proc[j])
        if pi == pj:
            if (i, j) in s.messages:
                out.append(f"edge ({i},{j}): same-processor edge carries "
                           f"a message")
            if float(s.start[j]) + tol < float(s.finish[i]):
                out.append(f"edge ({i},{j}): successor starts "
                           f"{s.start[j]:.6g} before predecessor "
                           f"finishes {s.finish[i]:.6g}")
            continue
        m = s.messages.get((i, j))
        if m is None:
            out.append(f"edge ({i},{j}): cross-processor edge "
                       f"p{pi}->p{pj} has no message placement")
            continue
        # route feasibility
        if m.src_proc != pi or m.dst_proc != pj:
            out.append(f"edge ({i},{j}): message endpoints p{m.src_proc}->"
                       f"p{m.dst_proc} do not match placements "
                       f"p{pi}->p{pj}")
        route = tuple(m.route)
        legal = [tuple(r) for r in tg.routes.get((pi, pj), [])]
        if route not in legal:
            out.append(f"edge ({i},{j}): route {route} is not a "
                       f"topology route p{pi}->p{pj}")
        hops = [l for (l, _st, _fi) in m.intervals]
        if hops != list(route):
            out.append(f"edge ({i},{j}): interval links {hops} do not "
                       f"follow route {route}")
        # hop timing: first hop after predecessor finish, hops ordered,
        # successor after final-hop LFT (Eqs. 13-14)
        prev_lst = -math.inf
        prev_lft = -math.inf
        for k, (l, lst, lft) in enumerate(m.intervals):
            if l in down_links:
                out.append(f"edge ({i},{j}): message occupies down "
                           f"link {l}")
            if not (math.isfinite(lst) and math.isfinite(lft)) \
                    or lft + tol < lst:
                out.append(f"edge ({i},{j}) hop {k} ({l}): malformed "
                           f"interval [{lst}, {lft}]")
                continue
            if k == 0 and lst + tol < float(s.finish[i]):
                out.append(f"edge ({i},{j}): first hop starts "
                           f"{lst:.6g} before predecessor finishes "
                           f"{s.finish[i]:.6g}")
            if lst + tol < prev_lst or lft + tol < prev_lft:
                out.append(f"edge ({i},{j}) hop {k} ({l}): hop timing "
                           f"not monotone along the route")
            prev_lst, prev_lft = lst, lft
        if m.intervals and float(s.start[j]) + tol < m.intervals[-1][2]:
            out.append(f"edge ({i},{j}): successor starts "
                       f"{s.start[j]:.6g} before message arrives "
                       f"{m.intervals[-1][2]:.6g}")

    # --- link-contention exclusivity ---
    by_link: Dict[str, List[Tuple[float, float, Tuple[int, int]]]] = {}
    for e, m in s.messages.items():
        for (l, lst, lft) in m.intervals:
            by_link.setdefault(l, []).append((lst, lft, e))
    for l, ivs in by_link.items():
        ivs.sort()
        for (s0, f0, e0), (s1, f1, e1) in zip(ivs, ivs[1:]):
            if f0 > s1 + tol:
                out.append(f"link {l}: messages {e0} and {e1} overlap "
                           f"([{s0:.6g}, {f0:.6g}] vs "
                           f"[{s1:.6g}, {f1:.6g}])")
    return out


def validate_schedule(s: Schedule,
                      spec: Optional[FaultSpec] = None) -> None:
    """Raise :class:`ScheduleValidationError` on any violation."""
    v = schedule_violations(s, spec)
    if v:
        raise ScheduleValidationError(v)


# ----------------------------------------------------------------------
# Session-boundary input validation (actionable one-line ValueErrors)
# ----------------------------------------------------------------------
def _finite_positive(x, what: str) -> None:
    try:
        v = float(x)
    except (TypeError, ValueError):
        raise ValueError(f"{what} must be a number, got {x!r}") from None
    if math.isnan(v):
        raise ValueError(f"{what} is NaN")
    if not math.isfinite(v) or v <= 0.0:
        raise ValueError(f"{what} must be finite and > 0, got {v!r}")


def check_task_rates(task_rates: Dict[int, float], n: int) -> None:
    """Reject NaN/zero/negative rate factors and unknown task ids."""
    for t, f in task_rates.items():
        if not isinstance(t, (int, np.integer)) or isinstance(t, bool) \
                or not 0 <= int(t) < n:
            raise ValueError(f"unknown task id {t!r} (graph has tasks "
                             f"0..{n - 1})")
        _finite_positive(f, f"task_rates[{t}]")


def check_link_speeds(link_speed: Dict[str, float], tg: Topology) -> None:
    """Reject NaN/zero/negative speeds and unknown link names."""
    unknown = sorted(set(link_speed) - set(tg.link_speed))
    if unknown:
        raise ValueError(f"unknown links {unknown} (topology links: "
                         f"{tg.all_links()})")
    for l, sp in link_speed.items():
        _finite_positive(sp, f"link_speed[{l!r}]")


def check_graph(g: SPG) -> None:
    """Reject malformed SPGs at the session boundary.

    ``SPG.__post_init__`` already rejects cycles and bad edges at
    construction; this re-derives the cheap invariants so a graph that
    was mutated (or constructed around the dataclass machinery) still
    fails with an actionable message instead of a deep engine error.
    """
    if not isinstance(g, SPG):
        raise ValueError(f"submit expects an SPG, got {type(g).__name__}")
    if g.n <= 0:
        raise ValueError("graph has no tasks")
    if len(g.topo_order) != g.n:
        raise ValueError("graph is cyclic: no topological order covers "
                         "every task")
    w = np.asarray(g.weights, dtype=float)
    if w.shape != (g.n,):
        raise ValueError(f"weights shape {w.shape} != ({g.n},)")
    if np.isnan(w).any():
        raise ValueError(f"task weights contain NaN (tasks "
                         f"{np.flatnonzero(np.isnan(w)).tolist()})")
    if not np.isfinite(w).all() or (w < 0).any():
        bad = np.flatnonzero(~np.isfinite(w) | (w < 0)).tolist()
        raise ValueError(f"task weights must be finite and >= 0 (tasks "
                         f"{bad})")
    if g.comp_matrix is not None:
        cm = np.asarray(g.comp_matrix, dtype=float)
        if not np.isfinite(cm).all() or (cm < 0).any():
            raise ValueError("explicit comp_matrix entries must be "
                             "finite and >= 0")


def check_topology(tg: Topology) -> None:
    """Reject malformed topologies when a session is created."""
    rates = np.asarray(tg.rates, dtype=float)
    if rates.shape != (tg.n_procs,) or not np.isfinite(rates).all() \
            or (rates <= 0).any():
        raise ValueError("processor rates must be finite and > 0 "
                         "(one per processor)")
    for l, sp in tg.link_speed.items():
        _finite_positive(sp, f"link speed of {l!r}")
    known = set(tg.link_speed)
    for pair, rr in tg.routes.items():
        for r in rr:
            missing = [l for l in r if l not in known]
            if missing:
                raise ValueError(f"route {r} of pair {pair} uses "
                                 f"unknown links {missing}")
