"""Unified scheduler session API: policies, multi-graph submission, and
incremental rescheduling.

The paper's DSMS setting is *register once, execute continuously*
(Section 4.4): schedules are recomputed whenever queries are added or
task computation times drift.  This module is the long-lived surface for
that loop — a :class:`Scheduler` session bound to one
:class:`~.topology.Topology`:

  * ``submit(spg) -> Plan`` compiles and caches a
    :class:`~.engine.CompiledInstance` per graph and runs the selected
    :class:`Policy` (the Algorithm-1 alpha sweep for the HVLB policies).
  * ``submit_many([spg, ...]) -> FleetPlan`` schedules several
    independent SPGs against *shared* link state in one engine pass —
    the exp6 fleet-serving scenario.  Internally the graphs are joined
    into one disjoint-union SPG whose merged priority queue preserves
    each graph's own dequeue order.
  * ``update(task_rates=..., link_speed=...) -> Plan`` re-plans after
    drift.  For task-rate drift it re-simulates only the *suffix* of the
    memoized decision trace that the drift can actually reach: rows of
    the computation/LDET matrices that changed (plus, under the
    worked-example CCR convention, successors whose inbound message
    volumes changed) mark the first queue position whose decision could
    differ; everything before it is re-committed from the trace
    checkpoint (see ``engine.DecisionTrace``).  The result is
    bit-identical to a from-scratch ``submit`` of the modified graph.

Policies are frozen dataclasses (hashable — they key the session's plan
and trace caches): :class:`HSV_CC` (baseline, Xie et al.),
:class:`HVLB_CC_A` / :class:`HVLB_CC_B` (Algorithm 1 with the Eq. 8 /
Eq. 9 prioritizer), and :class:`HVLB_CC_IC` — the Section-4.4 imprecise
computation model as a first-class policy whose :class:`Plan` carries
schedule holes and precision accessors instead of requiring post-hoc
helper calls.

Every schedule ultimately runs on a *candidate-evaluation backend*
(:mod:`repro.core.backends`): ``backend="auto"`` (default) picks the
(P,)-batch vector backend on wide topologies and the scalar reference
loop otherwise; ``backend="pallas"`` (opt-in, requires jax) runs each
decision's candidate batch in a Pallas device kernel.  The NumPy
backends are bit-identical and pallas is decision-identical (DESIGN
§5), so the knob (session constructor, per-call override, or the
``REPRO_SCHED_BACKEND`` environment variable) is about speed, not
results.  Backend/topology compatibility is validated when the name
resolves — before any session state is built.

The pre-existing one-shot functions (``schedule_hsv_cc``,
``schedule_hvlb_cc``, ``schedule_hvlb_cc_best``) remain as thin
deprecation shims over this module with bit-identical outputs
(``tests/test_engine_equivalence.py`` asserts shim == session ==
reference).
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .backends import (PALLAS, default_backend, resolve_backend_name,
                       vector_compatible)
from .deprecation import warn_once
from .engine import (DEFAULT_BATCH_MAX, CompiledInstance, DecisionTrace,
                     validate_batch)
from .faults import (Fault, FaultSpec, InfeasibleScheduleError,
                     LinkDegraded, LinkDown, ProcessorDown)
from .graph import SPG
from .imprecise import precision as _precision
from .imprecise import schedule_holes
from .ranks import hprv_a, hprv_b, ldet_cc, priority_queue, rank_matrix
from .scheduler import Schedule, SchedulingFailure, list_schedule
from .topology import Topology
from .validate import (check_graph, check_link_speeds, check_task_rates,
                       check_topology)

# Grid alphas closer than this to a predicted trace-flip point are
# re-simulated rather than skipped (guards the last-ulp difference between
# the linear prediction A + B*alpha and the simulated Def. 4.1 value).
_SKIP_MARGIN = 1e-6

# Backends the session demotes away from when they fail mid-plan (the
# fallback chain, DESIGN.md §6): only opt-in *device* backends — a NumPy
# backend error is a real bug and must surface.
_DEVICE_BACKENDS = (PALLAS,)

# (from, to) pairs already warned about — the fallback chain warns once
# per process, not once per submit.
_FALLBACK_WARNED: set = set()


def _warn_fallback(src: str, dst: str, err: BaseException) -> None:
    key = (src, dst)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    warnings.warn(
        f"scheduler backend {src!r} failed "
        f"({type(err).__name__}: {err}); demoting to {dst!r} "
        f"(decisions are backend-identical; further demotions of this "
        f"kind stay silent)", RuntimeWarning, stacklevel=4)


# ----------------------------------------------------------------------
# Policies
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HSV_CC:
    """Baseline policy (Xie et al. [25]): HPRV_A queue, EFT * LDET_CC
    selection — equivalent to HVLB_CC at alpha = 0, no sweep."""


@dataclasses.dataclass(frozen=True)
class HVLB_CC_A:
    """Algorithm 1 with the HSV prioritizer (Eq. 8): sweep alpha over
    ``[0, alpha_max]`` in ``alpha_step`` increments, keep min makespan.

    ``period`` is the application period of Definition 4.1 (the
    deadline/stream-rate requirement).  ``None`` pins the DAG's
    sum-of-min-computation proxy at first submission; the pinned value is
    reused by every :meth:`Scheduler.update` (``Plan.period`` exposes it).
    ``sweep="adaptive"`` is the opt-in coarse-to-fine grid.
    """

    alpha_max: float = 3.0
    alpha_step: float = 0.01
    period: Optional[float] = None
    sweep: str = "grid"
    coarse_factor: int = 10
    # adaptive-sweep refinement band: coarse grid points whose makespan is
    # within this *factor* of the coarse optimum get their neighbourhood
    # re-swept at the fine step (1.02 = the 2% band).  Pure sweep-cost
    # heuristic — it decides which alphas are simulated, never how any
    # committed decision is valued.
    refine_within: float = 1.02


@dataclasses.dataclass(frozen=True)
class HVLB_CC_B(HVLB_CC_A):
    """Algorithm 1 with the depth-damped prioritizer (Eq. 9) that orders
    arbitrary stream-processing graphs (see ``ranks.hprv_b``)."""

    depth_power: int = 2
    outd_mode: str = "indicator"


@dataclasses.dataclass(frozen=True)
class HVLB_CC_IC(HVLB_CC_B):
    """HVLB_CC (B) + the Section-4.4 imprecise-computation model: the
    resulting :class:`Plan` carries ``holes`` (Eqs. 20-21, with exit
    tasks that have nothing after them reported as ``inf``) and a
    ``precision(task, lam)`` accessor (Experiment 5)."""


Policy = Union[HSV_CC, HVLB_CC_A, HVLB_CC_B, HVLB_CC_IC]


# ----------------------------------------------------------------------
# Results
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SweepResult:
    """Alpha-sweep outcome (Fig. 5 data), as plotting-ready arrays.

    ``alphas[k]`` / ``makespans[k]`` are the grid point and its makespan.
    The legacy list-of-tuples representation survives only as the
    deprecated :attr:`curve` property.
    """

    best: Schedule
    best_alpha: float
    alphas: np.ndarray                   # (k,) grid alphas
    makespans: np.ndarray                # (k,) makespan per grid alpha

    @classmethod
    def from_points(cls, best: Schedule, best_alpha: float,
                    points: List[Tuple[float, float]]) -> "SweepResult":
        """Build from the sweep loops' (alpha, makespan) accumulator."""
        return cls(best, best_alpha,
                   np.array([a for a, _ in points], dtype=float),
                   np.array([m for _, m in points], dtype=float))

    @property
    def curve(self) -> List[Tuple[float, float]]:
        """Deprecated list-of-tuples view; use ``alphas``/``makespans``."""
        warn_once("SweepResult.curve",
                  "SweepResult.curve is deprecated; use the "
                  "SweepResult.alphas / SweepResult.makespans arrays")
        return list(zip(self.alphas.tolist(), self.makespans.tolist()))


@dataclasses.dataclass
class ReplayStats:
    """Decision-replay accounting for one submit/update."""

    suffix_start: int            # first re-simulated queue position
    decisions_simulated: int     # full candidate-loop evaluations
    decisions_replayed: int      # positions re-committed from the trace
    sims_resumed: int            # alpha points resumed from a trace
    sims_full: int               # alpha points simulated from scratch
    # queue positions a fault event invalidated (len(queue) - suffix_start
    # on fault-triggered replans; 0 on submits and benign-drift updates):
    # the prefix-survival counter asserted by the chaos tests / exp9
    invalidated_by_fault: int = 0
    # perturbation events folded into this replay: 1 for a submit or a
    # plain single-dict update, k when a batched ``update`` coalesced k
    # task-rate/link-speed dicts into one combined suffix replay (the
    # service coalescing layer's replan-count lever, exp10)
    coalesced: int = 1


@dataclasses.dataclass
class Plan:
    """Result of scheduling one graph under one policy."""

    schedule: Schedule
    policy: Policy
    graph: SPG
    period: Optional[float]      # effective (pinned) Def.-4.1 period
    sweep: Optional[SweepResult] = None
    holes: Optional[Dict[int, float]] = None     # HVLB_CC_IC only
    replay: Optional[ReplayStats] = None
    backend: Optional[str] = None    # resolved evaluator ("reference": None)
    batch: Optional[int] = None      # resolved level-batch cap (reference:
    #                                  None; decisions are batch-invariant)
    # backend demotions taken to produce this plan, oldest first:
    # (from_backend, to_backend, reason) triples — None when the requested
    # backend ran clean.  ``backend`` above is the evaluator that actually
    # produced the schedule (decisions are backend-identical, so a demoted
    # plan's schedule equals the one the requested backend would have made).
    fallback: Optional[Tuple[Tuple[str, str, str], ...]] = None

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    @property
    def proc(self) -> np.ndarray:
        return self.schedule.proc

    @property
    def best_alpha(self) -> Optional[float]:
        return self.sweep.best_alpha if self.sweep is not None else None

    def precision(self, task: int, lam: float) -> float:
        """Data precision of ``task`` at arrival rate ``lam`` (Exp. 5).

        Requires an imprecise-computation policy (:class:`HVLB_CC_IC`),
        which attaches the schedule holes to the plan.
        """
        if self.holes is None:
            raise ValueError("precision requires an HVLB_CC_IC policy "
                             "(this plan carries no schedule holes)")
        s = self.schedule
        mp = self.graph.comp(task, int(s.proc[task]), s.topology.rates)
        return _precision(mp, self.holes.get(task, 0.0), lam, ic=True)


@dataclasses.dataclass
class FleetPlan:
    """Joint schedule of several independent SPGs on one topology.

    ``schedule`` is the union schedule (tasks of graph ``k`` occupy node
    ids ``offsets[k] .. offsets[k] + graphs[k].n``); ``subschedule(k)``
    re-indexes graph ``k``'s slice back to its own node ids.
    """

    schedule: Schedule
    graphs: List[SPG]
    offsets: List[int]
    policy: Policy
    period: Optional[float]
    sweep: Optional[SweepResult] = None
    backend: Optional[str] = None
    batch: Optional[int] = None
    fallback: Optional[Tuple[Tuple[str, str, str], ...]] = None

    @property
    def makespan(self) -> float:
        return self.schedule.makespan

    def subschedule(self, k: int) -> Schedule:
        g, off = self.graphs[k], self.offsets[k]
        lo, hi = off, off + g.n
        msgs = {(i - off, j - off): dataclasses.replace(
                    m, edge=(i - off, j - off))
                for (i, j), m in self.schedule.messages.items()
                if lo <= i < hi}
        return Schedule(g, self.schedule.topology,
                        self.schedule.proc[lo:hi].copy(),
                        self.schedule.start[lo:hi].copy(),
                        self.schedule.finish[lo:hi].copy(),
                        msgs, alpha=self.schedule.alpha)


# ----------------------------------------------------------------------
# Internals
# ----------------------------------------------------------------------
def _queue_key(policy: Policy) -> tuple:
    if isinstance(policy, HVLB_CC_B):        # covers HVLB_CC_IC
        return ("b", policy.depth_power, policy.outd_mode)
    return ("a",)                            # HSV_CC and HVLB_CC_A share Eq. 8


class _GraphSession:
    """Cached per-graph state of one Scheduler session.

    The compiled instance is built lazily: :meth:`Scheduler.probe_update`
    only needs ranks/LDET/queues to measure how much of a memoized trace
    a prospective drift would invalidate.
    """

    __slots__ = ("g", "handles", "rank", "ldet", "queues", "periods",
                 "traces", "plans", "_tg", "_compiled", "_inst", "_faults")

    def __init__(self, g: SPG, tg: Topology, compiled: bool,
                 faults: Optional[FaultSpec] = None,
                 rank: Optional[np.ndarray] = None,
                 ldet: Optional[np.ndarray] = None) -> None:
        self.g = g
        self.handles = [g]      # graph objects that address this session
        self._tg = tg
        self._compiled = compiled
        # active resource faults at session-build time; the compiled
        # instance embeds their masking, so the session cache is cleared
        # whenever the spec changes (Scheduler._fault_event).  Rank/LDET
        # stay those of the *healthy* system (DESIGN.md §6) and may be
        # handed over from a superseded session of the same (g, tg).
        self._faults = None if faults is None or faults.is_empty else faults
        self._inst: Optional[CompiledInstance] = None
        self.rank = rank_matrix(g, tg) if rank is None else rank
        self.ldet = ldet_cc(g, tg, self.rank) if ldet is None else ldet
        self.queues: Dict[tuple, List[int]] = {}
        self.periods: Dict[Policy, float] = {}
        # traces are shared across backends and batch caps (records are
        # backend-portable, decisions batch-invariant); plans are keyed
        # by (policy, backend, batch) so a per-call override never hands
        # back a stale plan object
        self.traces: Dict[Policy, Dict[float, DecisionTrace]] = {}
        self.plans: Dict[Tuple[Policy, Optional[str], Optional[int]],
                         Plan] = {}

    @property
    def inst(self) -> Optional[CompiledInstance]:
        if self._compiled and self._inst is None:
            self._inst = CompiledInstance(self.g, self._tg, rank=self.rank,
                                          ldet=self.ldet,
                                          faults=self._faults)
        return self._inst

    def queue_for(self, tg: Topology, policy: Policy) -> List[int]:
        key = _queue_key(policy)
        q = self.queues.get(key)
        if q is None:
            g, rank = self.g, self.rank
            if key[0] == "b":
                prv = hprv_b(g, tg, rank, depth_power=policy.depth_power,
                             outd_mode=policy.outd_mode)
            else:
                prv = hprv_a(g, tg, rank)
            q = priority_queue(prv, rank.mean(axis=1))
            self.queues[key] = q
        return q

    def default_period(self, tg: Topology) -> float:
        return self.g.default_period(tg.rates, tg.n_procs)


def _rescaled_graph(g: SPG, events: Sequence[Dict[int, float]]) -> SPG:
    """The graph after arrival-rate drift: task ``t``'s computational
    volume scales by ``ev[t]`` for each event dict in order (Eq. 19's
    lambda on the mandatory part).  Factors are applied sequentially —
    ``(w * f1) * f2``, never ``w * (f1 * f2)`` — so one batched replay is
    bit-identical to replaying the events one ``update()`` at a time.
    Structure, explicit edge volumes, and names are preserved."""
    w = g.weights.copy()
    cm = None if g.comp_matrix is None else np.array(g.comp_matrix,
                                                     dtype=float)
    for ev in events:
        for t, f in ev.items():
            if not 0 <= t < g.n:
                raise ValueError(f"task {t} out of range")
            w[t] *= f
            if cm is not None:
                cm[t] *= f
    g2 = SPG(n=g.n, edges=list(g.edges), weights=w, tpl=dict(g.tpl),
             tpl_proportional_ccr=g.tpl_proportional_ccr,
             comp_matrix=cm, name=g.name)
    return g2


def _as_events(arg) -> List[dict]:
    """Normalize an ``update`` perturbation argument — one dict or a
    sequence of dicts (a batch of drift events, oldest first) — to a
    list of dicts."""
    if arg is None:
        return []
    if isinstance(arg, dict):
        return [arg]
    return [dict(ev) for ev in arg]


def _disjoint_union(graphs: Sequence[SPG], tg: Topology) -> Tuple[SPG,
                                                                  List[int]]:
    ccrs = {g.tpl_proportional_ccr for g in graphs}
    if len(ccrs) > 1:
        raise ValueError("submit_many requires every graph to share the "
                         "same tpl convention (tpl_proportional_ccr)")
    explicit = any(g.comp_matrix is not None for g in graphs)
    offsets: List[int] = []
    weights: List[float] = []
    edges: List[Tuple[int, int]] = []
    tpl: Dict[Tuple[int, int], float] = {}
    comp_rows: List[np.ndarray] = []
    off = 0
    for g in graphs:
        offsets.append(off)
        weights.extend(g.weights.tolist())
        edges.extend((i + off, j + off) for (i, j) in g.edges)
        tpl.update({(i + off, j + off): v for (i, j), v in g.tpl.items()})
        if explicit:
            comp_rows.append(g.comp_matrix_for(tg.rates))
        off += g.n
    union = SPG(n=off, edges=edges, weights=np.asarray(weights),
                tpl=tpl, tpl_proportional_ccr=next(iter(ccrs)),
                comp_matrix=np.vstack(comp_rows) if explicit else None,
                name=f"fleet[{len(graphs)}]")
    return union, offsets


# ----------------------------------------------------------------------
# The session
# ----------------------------------------------------------------------
class Scheduler:
    """Long-lived scheduling session bound to one :class:`Topology`.

    ``engine="compiled"`` (default) runs every policy on shared
    :class:`CompiledInstance` state with decision-trace memoization;
    ``engine="reference"`` re-runs the readable ``list_schedule`` per
    grid point (bit-identical results, no incremental replay — updates
    fall back to a full re-plan).

    ``backend`` selects the compiled engine's candidate-evaluation
    backend (:mod:`repro.core.backends`): ``"scalar"``, ``"vector"``,
    ``"pallas"`` (opt-in device kernel, requires jax), or ``"auto"``
    (the default — vector from P >= 8; overridable per process via the
    ``REPRO_SCHED_BACKEND`` environment variable).  The NumPy backends
    are bit-identical and pallas decision-identical, so this is a
    performance knob; ``submit``/``submit_many``/``update`` accept a
    per-call override.  An explicit backend incompatible with the
    session topology raises :class:`~.backends.BackendCompatError` at
    resolve time, leaving the session's caches untouched.

    ``batch`` caps the engine's level-batch size — how many independent
    same-rank-level tasks the decision layer hands to the backend per
    ``evaluate_batch`` wave (``None`` = the engine default,
    :data:`~.engine.DEFAULT_BATCH_MAX`; ``1`` = strict per-decision
    walk).  Decisions are batch-invariant, so this is purely a
    performance knob for device backends (one kernel launch and one
    host round-trip per wave); like ``backend`` it keys the plan cache
    and accepts a per-call override.
    """

    def __init__(self, topology: Topology, policy: Optional[Policy] = None,
                 engine: str = "compiled",
                 backend: Optional[str] = None,
                 batch: Optional[int] = None,
                 faults: Iterable[Fault] = (),
                 wave_timeout: Optional[float] = None) -> None:
        if engine not in ("compiled", "reference"):
            raise ValueError(f"unknown engine {engine!r}")
        check_topology(topology)
        self.topology = topology
        self.policy: Policy = HVLB_CC_B() if policy is None else policy
        self.engine = engine
        self.backend = backend
        self.batch = validate_batch(batch)
        # active resource faults: start from ``faults`` (so a restarted
        # service can resume a degraded fleet), grown/shrunk by
        # mark_failed/degrade/restore.  ComputeSpike is graph drift, not
        # resource state — FaultSpec.from_faults rejects it here.
        self._spec = FaultSpec.from_faults(faults, topology)
        # engine watchdog: per-wave wall-clock budget (seconds) applied to
        # *device* backends only — a wave overrun raises WaveTimeoutError,
        # which the fallback chain demotes on.  None (default, or env
        # REPRO_SCHED_WAVE_TIMEOUT unset/empty) disables the watchdog.
        if wave_timeout is None:
            env = os.environ.get("REPRO_SCHED_WAVE_TIMEOUT", "")
            wave_timeout = float(env) if env else None
        if wave_timeout is not None and wave_timeout <= 0:
            raise ValueError(f"wave_timeout must be > 0 seconds, got "
                             f"{wave_timeout!r}")
        self.wave_timeout = wave_timeout
        self._sessions: Dict[int, _GraphSession] = {}
        self._last: Optional[_GraphSession] = None
        # probe_update's dry-run state, reused by a matching update()
        self._probe: Optional[tuple] = None

    def _resolve_batch(self, batch: Optional[int]) -> Optional[int]:
        """Concrete level-batch cap for this call (None for reference —
        the readable reference walks one decision at a time).

        The value is validated (``engine.validate_batch``, the single
        source of truth) even under the reference engine, so an invalid
        ``batch=`` fails loudly instead of being silently ignored until
        the session switches to the compiled engine.
        """
        b = self.batch if batch is None else validate_batch(batch)
        if self.engine != "compiled":
            return None
        return DEFAULT_BATCH_MAX if b is None else b

    def _resolve_backend(self, backend: Optional[str]) -> Optional[str]:
        """Concrete evaluator name for this call (None for reference).

        The name is validated even under the reference engine, so a
        typo'd ``backend=`` fails loudly instead of being silently
        ignored until the session switches to the compiled engine.
        """
        name = resolve_backend_name(
            self.backend if backend is None else backend,
            self.topology.n_procs, self.topology)
        return name if self.engine == "compiled" else None

    def _resolve_backend_fb(self, backend: Optional[str]
                            ) -> Tuple[Optional[str],
                                       Tuple[Tuple[str, str, str], ...]]:
        """Resolve with the fallback chain's resolve-time demotion.

        A requested *device* backend that cannot even resolve (jax
        missing / broken install) demotes to the chain's next NumPy
        backend instead of raising — the session must survive a broken
        opt-in accelerator — and the pending ``(from, to, reason)``
        record is attached to the produced plan.  Everything else
        (unknown names, vector-incompatibility) raises exactly like
        :meth:`_resolve_backend`.
        """
        req = self.backend if backend is None else backend
        if req is None:
            req = default_backend()
        try:
            return self._resolve_backend(req), ()
        except Exception as e:
            if req not in _DEVICE_BACKENDS:
                raise
            target = self._fallback_chain(req)[1]
            _warn_fallback(req, target, e)
            return (target if self.engine == "compiled" else None,
                    ((req, target, f"{type(e).__name__}: {e}"),))

    def _fallback_chain(self, name: Optional[str]) -> List[str]:
        """Demotion order starting at ``name`` (device backends only
        grow a tail: pallas -> vector (when route-compatible) -> scalar)."""
        chain = [name]
        if name in _DEVICE_BACKENDS:
            if vector_compatible(self.topology):
                chain.append("vector")
            chain.append("scalar")
        return chain

    # ------------------------------------------------------------- submit
    def submit(self, g: SPG, policy: Optional[Policy] = None,
               backend: Optional[str] = None,
               batch: Optional[int] = None) -> Plan:
        """Compile (once) and schedule ``g`` under ``policy``.

        Re-submitting the same graph object reuses its compiled instance,
        priority queues, and — for an unchanged (policy, backend, batch)
        — the cached plan.
        """
        policy = self.policy if policy is None else policy
        bname, pending = self._resolve_backend_fb(backend)
        bcap = self._resolve_batch(batch)
        sess = self._sessions.get(id(g))
        if sess is None or sess.g is not g:
            check_graph(g)       # actionable errors at the boundary
            sess = _GraphSession(g, self.topology,
                                 compiled=self.engine == "compiled",
                                 faults=self._spec)
            self._sessions[id(g)] = sess
        self._last = sess
        plan = sess.plans.get((policy, bname, bcap))
        if plan is None:
            plan = self._plan_fb(sess, policy, backend=bname, batch=bcap,
                                 pending=pending)
            sess.plans[(policy, bname, bcap)] = plan
        return plan

    def submit_many(self, graphs: Iterable[SPG],
                    policy: Optional[Policy] = None,
                    backend: Optional[str] = None,
                    batch: Optional[int] = None) -> FleetPlan:
        """Schedule several independent SPGs against shared link state in
        one engine pass (the exp6 fleet scenario).

        The graphs are joined into one disjoint-union SPG; the merged
        priority queue is the stable merge of the per-graph queues (the
        global HPRV sort restricted to one graph's nodes reproduces that
        graph's own queue), so precedence safety per graph is preserved.
        The union session stays cached: a later ``update(task_rates=...)``
        (keyed by union node ids) replays the fleet schedule
        incrementally.
        """
        graphs = list(graphs)
        if not graphs:
            raise ValueError("submit_many needs at least one graph")
        policy = self.policy if policy is None else policy
        union, offsets = _disjoint_union(graphs, self.topology)
        plan = self.submit(union, policy, backend=backend, batch=batch)
        return FleetPlan(schedule=plan.schedule, graphs=graphs,
                         offsets=offsets, policy=policy,
                         period=plan.period, sweep=plan.sweep,
                         backend=plan.backend, batch=plan.batch,
                         fallback=plan.fallback)

    # ------------------------------------------------------------- update
    def probe_update(self, *, task_rates: Dict[int, float],
                     graph: Optional[SPG] = None,
                     policy: Optional[Policy] = None) -> int:
        """Dry-run of ``update(task_rates=...)``: how many leading
        decisions of the memoized trace provably survive the drift.

        Costs one vectorized rank/LDET recomputation — no scheduling.
        ``n`` (every decision survives — the drift is invisible to this
        policy) down to ``0`` (full re-simulation).  A matching
        ``update()`` right after reuses the probe's prepared state, so
        probing before updating costs nothing extra.
        """
        policy = self.policy if policy is None else policy
        sess = self._session_of(graph)
        if sess is None:
            raise ValueError("probe_update() before any submit()")
        check_task_rates(task_rates, sess.g.n)
        changed = {t: f for t, f in task_rates.items() if f != 1.0}
        queue_len = len(sess.queue_for(self.topology, policy))
        if not changed:
            return queue_len
        if self.engine != "compiled":
            return 0
        new_sess = _GraphSession(_rescaled_graph(sess.g, [changed]),
                                 self.topology, compiled=True,
                                 faults=self._spec)
        prefix = self._clean_prefix(sess, new_sess, policy)
        self._probe = (sess, policy, tuple(sorted(changed.items())),
                       new_sess, prefix)
        return prefix

    def update(self, *,
               task_rates: Union[Dict[int, float],
                                 Sequence[Dict[int, float]], None] = None,
               link_speed: Union[Dict[str, float],
                                 Sequence[Dict[str, float]], None] = None,
               graph: Optional[SPG] = None,
               policy: Optional[Policy] = None,
               backend: Optional[str] = None,
               batch: Optional[int] = None) -> Plan:
        """Re-plan after drift, replaying only the affected trace suffix.

        ``task_rates`` maps task -> arrival-rate factor on its
        computational volume; ``link_speed`` overrides named link speeds
        of the session topology (which invalidates every cached instance
        — LDET and all message timings change, so the whole trace is
        re-simulated).  Both accept either one dict or a *sequence* of
        dicts — a batch of pending perturbation events, oldest first —
        in which case the k events are folded into ONE combined suffix
        replay (task factors compose sequentially, later link-speed
        overrides win) whose result is bit-identical to applying the
        events through k separate ``update()`` calls;
        ``ReplayStats.coalesced`` records the fold.  This is the
        coalescing primitive of the serving layer (``repro.service``).
        ``graph`` selects which submitted graph to update (default: the
        most recently submitted).  The returned plan is bit-identical to
        a from-scratch ``submit`` of the modified graph under the same
        pinned period (``Plan.period``).
        """
        policy = self.policy if policy is None else policy
        sess = self._session_of(graph)
        if sess is None:
            raise ValueError("update() before any submit(): the session "
                             "has no graph to re-plan")
        tr_events = _as_events(task_rates)
        ls_events = [ev for ev in _as_events(link_speed) if ev]
        for ev in tr_events:
            check_task_rates(ev, sess.g.n)
        for ev in ls_events:
            check_link_speeds(ev, self.topology)
        changed_events = [ce for ce in
                          ({t: f for t, f in ev.items() if f != 1.0}
                           for ev in tr_events) if ce]
        link_changed = bool(ls_events)
        n_events = len(changed_events) + len(ls_events)

        if link_changed:
            speeds = dict(self.topology.link_speed)
            for ev in ls_events:
                speeds.update(ev)
            self.topology = Topology(
                list(self.topology.proc_names), self.topology.rates.copy(),
                speeds, {pair: list(rr)
                         for pair, rr in self.topology.routes.items()},
                ctml_mode=self.topology.ctml_mode)
            # every compiled instance embeds the old link speeds
            self._sessions = {}

        if not changed_events and not link_changed:
            self._sessions[id(sess.g)] = sess
            self._last = sess
            return self.submit(sess.g, policy, backend=backend, batch=batch)

        probe = self._probe
        self._probe = None
        if probe is not None and not link_changed \
                and len(changed_events) == 1 and probe[:3] == (
                    sess, policy, tuple(sorted(changed_events[0].items()))):
            new_sess, suffix_start = probe[3], probe[4]
            new_g = new_sess.g
        else:
            new_g = _rescaled_graph(sess.g, changed_events) \
                if changed_events else sess.g
            new_sess = _GraphSession(new_g, self.topology,
                                     compiled=self.engine == "compiled",
                                     faults=self._spec)
            suffix_start = 0
            if self.engine == "compiled" and not link_changed:
                suffix_start = self._clean_prefix(sess, new_sess, policy)
        new_sess.periods = dict(sess.periods)    # keep the pinned period

        prev_traces: Optional[Dict[float, DecisionTrace]] = None
        if suffix_start > 0:
            prev_traces = sess.traces.get(policy)

        bname, pending = self._resolve_backend_fb(backend)
        bcap = self._resolve_batch(batch)
        plan = self._plan_fb(new_sess, policy, prev_traces=prev_traces,
                             suffix_start=suffix_start, backend=bname,
                             batch=bcap, pending=pending)
        plan.replay.coalesced = max(1, n_events)
        new_sess.plans[(policy, bname, bcap)] = plan
        # the originally submitted handle and the new graph both address
        # this session; every map entry still pointing at the superseded
        # session is evicted (else each update would leak one session)
        new_sess.handles = [sess.handles[0], new_g]
        self._sessions = {k: v for k, v in self._sessions.items()
                          if v is not sess}
        for h in new_sess.handles:
            self._sessions[id(h)] = new_sess
        self._last = new_sess
        return plan

    # ------------------------------------------------------------- faults
    @property
    def faults(self) -> FaultSpec:
        """The active resource-fault spec (empty when healthy)."""
        return self._spec

    def mark_failed(self, *, proc: Optional[int] = None,
                    link: Optional[str] = None,
                    graph: Optional[SPG] = None,
                    policy: Optional[Policy] = None,
                    backend: Optional[str] = None,
                    batch: Optional[int] = None) -> Optional[Plan]:
        """Record a hard resource failure and replan around it.

        Exactly one of ``proc`` (processor index — :class:`ProcessorDown`)
        or ``link`` (link name — :class:`LinkDown`) must be given.  The
        replan invalidates exactly the decision-trace suffix that touches
        the failed resource: for a processor, positions from its first
        placement; for a link, positions from the first committed message
        interval on it (everything earlier is provably unchanged — the
        priorities stay healthy and a masked resource only worsens losing
        candidates, see DESIGN.md §6).  ``ReplayStats.invalidated_by_fault``
        on the returned plan counts the invalidated positions.

        Raises :class:`InfeasibleScheduleError` when some task has no
        feasible placement left; the fault stays recorded either way.
        Returns ``None`` when called before any ``submit`` (the fault is
        recorded and applies to every later submit).
        """
        if (proc is None) == (link is None):
            raise ValueError("mark_failed needs exactly one of "
                             "proc=<index> or link=<name>")
        fault: Fault = ProcessorDown(int(proc)) if proc is not None \
            else LinkDown(link)
        return self._apply_fault(fault, graph, policy, backend, batch)

    def degrade(self, *, link: Optional[str] = None,
                task: Optional[int] = None, factor: float,
                graph: Optional[SPG] = None,
                policy: Optional[Policy] = None,
                backend: Optional[str] = None,
                batch: Optional[int] = None) -> Optional[Plan]:
        """Record a soft degradation and replan.

        ``link=`` sets the link's slowdown factor (CTML of every message
        on it scales by ``factor``; ``factor=1`` restores nominal speed).
        ``task=`` is a :class:`ComputeSpike`: the task's computational
        volume scales by ``factor`` via the ``update(task_rates=...)``
        drift machinery (it rescales the *current* graph, so two spikes
        of 2.0 compose to 4.0).  Suffix invalidation follows the same
        trace-scan rule as :meth:`mark_failed`; a degradation that makes
        a link *faster* than before (factor below the previous one)
        conservatively invalidates the whole trace.
        """
        if (link is None) == (task is None):
            raise ValueError("degrade needs exactly one of link=<name> "
                             "or task=<index>")
        if task is not None:
            plan = self.update(task_rates={int(task): float(factor)},
                               graph=graph, policy=policy, backend=backend,
                               batch=batch)
            plan.replay.invalidated_by_fault = \
                plan.graph.n - plan.replay.suffix_start
            return plan
        return self._apply_fault(LinkDegraded(link, float(factor)),
                                 graph, policy, backend, batch)

    def restore(self, *, proc: Optional[int] = None,
                link: Optional[str] = None,
                graph: Optional[SPG] = None,
                policy: Optional[Policy] = None,
                backend: Optional[str] = None,
                batch: Optional[int] = None) -> Optional[Plan]:
        """Clear a recorded fault and replan (full re-simulation: a
        restored resource can improve *any* decision, so no prefix is
        provably unchanged).  No-op replan if the resource was healthy."""
        if (proc is None) == (link is None):
            raise ValueError("restore needs exactly one of proc=<index> "
                             "or link=<name>")
        new_spec = self._spec.without(proc=proc, link=link)
        return self._fault_event(new_spec, None, graph, policy, backend,
                                 batch)

    def _apply_fault(self, fault: Fault, graph: Optional[SPG],
                     policy: Optional[Policy], backend: Optional[str],
                     batch: Optional[int]) -> Optional[Plan]:
        new_spec = self._spec.with_fault(fault, self.topology)
        scan: Optional[tuple] = None
        if isinstance(fault, ProcessorDown):
            scan = ("proc", fault.proc)
        else:                    # LinkDown / LinkDegraded
            old_f = self._spec.link_factor(fault.link)
            new_f = new_spec.link_factor(fault.link)
            if new_f >= old_f:
                # strictly-worse (or unchanged) link: the trace prefix
                # whose committed messages avoid it is provably unchanged
                scan = ("link", self.topology.link_index()[fault.link])
            # a *faster* link can improve any decision: scan stays None
            # (conservative full invalidation)
        return self._fault_event(new_spec, scan, graph, policy, backend,
                                 batch)

    def _fault_event(self, new_spec: FaultSpec, scan: Optional[tuple],
                     graph: Optional[SPG], policy: Optional[Policy],
                     backend: Optional[str], batch: Optional[int]
                     ) -> Optional[Plan]:
        policy = self.policy if policy is None else policy
        sess = self._session_of(graph)
        self._spec = new_spec
        # every cached session embeds the previous spec's masking
        self._sessions = {}
        self._probe = None
        if sess is None:
            self._last = None
            return None          # recorded; applies to every later submit
        queue = sess.queue_for(self.topology, policy)
        suffix_start = 0
        if self.engine == "compiled" and scan is not None:
            traces = sess.traces.get(policy)
            if traces:
                suffix_start = min(
                    self._fault_prefix(tr, scan) for tr in traces.values())
        prev_traces = sess.traces.get(policy) if suffix_start > 0 else None
        new_sess = _GraphSession(sess.g, self.topology,
                                 compiled=self.engine == "compiled",
                                 faults=new_spec,
                                 rank=sess.rank, ldet=sess.ldet)
        new_sess.queues = dict(sess.queues)      # healthy heuristics
        new_sess.periods = dict(sess.periods)    # keep the pinned period
        bname, pending = self._resolve_backend_fb(backend)
        bcap = self._resolve_batch(batch)
        try:
            plan = self._plan_fb(new_sess, policy, prev_traces=prev_traces,
                                 suffix_start=suffix_start, backend=bname,
                                 batch=bcap, pending=pending,
                                 invalidated=len(queue) - suffix_start)
        except InfeasibleScheduleError:
            # the fault stays recorded and the stale sessions stay
            # dropped: later submits keep raising until restore()
            self._last = None
            raise
        new_sess.plans[(policy, bname, bcap)] = plan
        new_sess.handles = list(sess.handles)
        for h in new_sess.handles:
            self._sessions[id(h)] = new_sess
        self._last = new_sess
        return plan

    @staticmethod
    def _fault_prefix(trace: DecisionTrace, scan: tuple) -> int:
        """First trace position touching the failed resource (trace
        length when none does — the whole trace survives)."""
        kind, ident = scan
        if kind == "proc":
            for k, rec in enumerate(trace.records):
                if rec[1] == ident:
                    return k
        else:
            for k, rec in enumerate(trace.records):
                for (_i, _route, iv) in rec[4]:
                    for (lid, _s, _f) in iv:
                        if lid == ident:
                            return k
        return len(trace.records)

    def _session_of(self, graph: Optional[SPG]) -> Optional[_GraphSession]:
        if graph is None:
            return self._last
        sess = self._sessions.get(id(graph))
        # identity check guards against id() reuse after a submitted graph
        # handle was garbage-collected
        if sess is not None and not any(h is graph for h in sess.handles):
            return None
        return sess

    def _clean_prefix(self, old: _GraphSession, new: _GraphSession,
                      policy: Policy) -> int:
        """First queue position whose decision the drift can reach.

        A position's decision (and its committed floats) depends only on
        the task's comp/LDET rows, its inbound message volumes, the
        shared period, and the state left by earlier positions.  Rows are
        compared exactly (vectorized recomputation is deterministic), so
        any position before the first affected one is provably unchanged
        and can be re-committed from the memoized trace.
        """
        tg = self.topology
        old_q = old.queue_for(tg, policy)
        new_q = new.queue_for(tg, policy)
        prefix = 0
        for a, b in zip(old_q, new_q):
            if a != b:
                break
            prefix += 1
        comp_old = old.g.comp_matrix_for(tg.rates)
        comp_new = new.g.comp_matrix_for(tg.rates)
        comp_diff = np.any(comp_old != comp_new, axis=1)
        row_diff = comp_diff | np.any(old.ldet != new.ldet, axis=1)
        affected = set(np.flatnonzero(row_diff).tolist())
        if new.g.tpl_proportional_ccr is not None:
            # tpl(e_ij | p) = CCR * comp(i, p): successors' inbound
            # message volumes changed with the source's comp row
            for i in np.flatnonzero(comp_diff).tolist():
                affected.update(new.g.succ[i])
        if affected:
            pos = {t: k for k, t in enumerate(new_q)}
            prefix = min(prefix, min(pos[t] for t in affected))
        return prefix

    # -------------------------------------------------------------- plan
    def _plan_fb(self, sess: _GraphSession, policy: Policy,
                 prev_traces: Optional[Dict[float, DecisionTrace]] = None,
                 suffix_start: int = 0,
                 backend: Optional[str] = None,
                 batch: Optional[int] = None,
                 pending: Tuple[Tuple[str, str, str], ...] = (),
                 invalidated: int = 0) -> Plan:
        """Run :meth:`_plan` under the backend fallback chain.

        A *device* backend (pallas) failing with a compile/runtime error
        or a :class:`~.faults.WaveTimeoutError` demotes to the next
        backend in :meth:`_fallback_chain` for this plan — decisions are
        backend-identical, so the demoted plan's schedule is the one the
        requested backend would have produced.  Semantic scheduler errors
        (:class:`~.faults.InfeasibleScheduleError`,
        :class:`~.scheduler.SchedulingFailure`) always propagate: they
        would reproduce on any backend.  Each demotion is recorded on
        ``Plan.fallback`` and warned once per process; ``pending``
        carries demotions already taken at backend-resolve time.
        """
        chain = self._fallback_chain(backend)
        records = list(pending)
        for k, name in enumerate(chain):
            inst = sess.inst
            device = name in _DEVICE_BACKENDS
            if inst is not None and device:
                inst.wave_timeout = self.wave_timeout
            try:
                plan = self._plan(sess, policy, prev_traces=prev_traces,
                                  suffix_start=suffix_start, backend=name,
                                  batch=batch, invalidated=invalidated)
            except (InfeasibleScheduleError, SchedulingFailure):
                raise
            except Exception as e:
                if not device or k + 1 >= len(chain):
                    raise
                records.append((name, chain[k + 1],
                                f"{type(e).__name__}: {e}"))
                _warn_fallback(name, chain[k + 1], e)
                continue
            finally:
                if inst is not None:
                    inst.wave_timeout = None
            if records:
                plan.fallback = tuple(records)
            return plan
        raise AssertionError("unreachable: fallback chain exhausted")

    def _plan(self, sess: _GraphSession, policy: Policy,
              prev_traces: Optional[Dict[float, DecisionTrace]] = None,
              suffix_start: int = 0,
              backend: Optional[str] = None,
              batch: Optional[int] = None,
              invalidated: int = 0) -> Plan:
        g = sess.g
        queue = sess.queue_for(self.topology, policy)
        inst = sess.inst
        sim0 = inst.n_decisions_simulated if inst is not None else 0
        rep0 = inst.n_decisions_replayed if inst is not None else 0
        sims_resumed = sims_full = 0

        if isinstance(policy, HSV_CC):
            # alpha = 0 makes the period irrelevant to the schedule, but it
            # is pinned anyway so resumed traces stay self-consistent
            period = sess.periods.get(policy)
            if period is None:
                period = sess.default_period(self.topology)
                sess.periods[policy] = period
            if inst is None:
                best = list_schedule(g, self.topology, queue, sess.rank,
                                     alpha=0.0, ldet=sess.ldet)
                sims_full = 1
                sweep = None
            else:
                prev = (prev_traces or {}).get(0.0)
                pos = suffix_start if prev is not None else 0
                best, _, tr = inst.schedule_traced(
                    queue, 0.0, period=period, want_bound=False,
                    resume=prev, resume_pos=pos, backend=backend,
                    batch=batch)
                sess.traces[policy] = {0.0: tr}
                sims_resumed, sims_full = (1, 0) if pos else (0, 1)
                sweep = None
        else:
            if policy.sweep not in ("grid", "adaptive"):
                raise ValueError(f"unknown sweep {policy.sweep!r}")
            if inst is None and policy.sweep != "grid":
                raise ValueError("sweep='adaptive' requires "
                                 "engine='compiled'")
            period = sess.periods.get(policy)
            if period is None:
                period = policy.period if policy.period is not None \
                    else sess.default_period(self.topology)
                sess.periods[policy] = period
            if inst is None:
                sweep = self._sweep_reference(sess, queue, policy, period)
                sims_full = len(sweep.alphas)
            else:
                traces: Dict[float, DecisionTrace] = {}
                sweep, sims_resumed, sims_full = self._sweep_compiled(
                    inst, queue, policy, period, traces,
                    prev_traces, suffix_start, backend, batch)
                sess.traces[policy] = traces
            best = sweep.best

        replay = ReplayStats(
            suffix_start=suffix_start,
            decisions_simulated=(inst.n_decisions_simulated - sim0)
            if inst is not None else sims_full * g.n,
            decisions_replayed=(inst.n_decisions_replayed - rep0)
            if inst is not None else 0,
            sims_resumed=sims_resumed, sims_full=sims_full,
            invalidated_by_fault=invalidated)
        holes = schedule_holes(best, include_unbounded=True) \
            if isinstance(policy, HVLB_CC_IC) else None
        return Plan(schedule=best, policy=policy, graph=g, period=period,
                    sweep=sweep, holes=holes, replay=replay,
                    backend=backend, batch=batch)

    # ------------------------------------------------------------- sweeps
    def _sweep_compiled(self, inst: CompiledInstance, queue: Sequence[int],
                        policy: HVLB_CC_A, period: float,
                        traces: Dict[float, DecisionTrace],
                        prev_traces: Optional[Dict[float, DecisionTrace]],
                        suffix_start: int,
                        backend: Optional[str] = None,
                        batch: Optional[int] = None
                        ) -> Tuple[SweepResult, int, int]:
        n_steps = int(round(policy.alpha_max / policy.alpha_step))
        counters = [0, 0]                      # [resumed, full]

        if policy.sweep == "grid" and n_steps == 0:
            # single-point grid (the online re-plan unit): no rival alphas
            # to bound against, so skip the per-decision crossing tracking.
            # The schedule floats are unaffected by bound tracking, and the
            # grid shape is a pure function of the policy, so resume traces
            # stay consistent across updates.
            prev = (prev_traces or {}).get(0.0)
            pos = suffix_start if prev is not None else 0
            s, _, tr = inst.schedule_traced(queue, 0.0, period=period,
                                            want_bound=False,
                                            resume=prev, resume_pos=pos,
                                            backend=backend, batch=batch)
            traces[0.0] = tr
            return (SweepResult.from_points(s, 0.0, [(0.0, s.makespan)]),
                    1 if pos else 0, 0 if pos else 1)

        if policy.sweep == "grid" and not (prev_traces and suffix_start) \
                and inst.sweep_supported(backend):
            # (A, B) fused sweep (DESIGN.md §5): every grid alpha's whole
            # schedule in ONE device dispatch.  Fresh grids only — a
            # resumable update goes through the host loop below, which
            # replays per-alpha trace prefixes.  Selection matches the
            # host loop exactly: trace-invariance means the alphas the
            # host loop would have skipped produce bit-equal schedules
            # here, and the same strict-improvement rule scans them in
            # the same order.
            alphas = [k * policy.alpha_step for k in range(n_steps + 1)]
            swept = inst.schedule_sweep(queue, alphas, period=period,
                                        backend=backend, batch=batch)
            fbest: Optional[Schedule] = None
            fbest_alpha = 0.0
            fpoints: List[Tuple[float, float]] = []
            for alpha, (s, _bnd, tr) in zip(alphas, swept):
                traces[alpha] = tr
                fpoints.append((alpha, s.makespan))
                # analysis: allow[float-arith] strict-improvement epsilon on a reduction over backend outputs, not a per-decision value
                if fbest is None or s.makespan < fbest.makespan - 1e-12:
                    fbest, fbest_alpha = s, alpha
            assert fbest is not None
            return (SweepResult.from_points(fbest, fbest_alpha, fpoints),
                    0, len(alphas))

        def grid_pass(alphas: Sequence[float], points, best, best_alpha):
            k = 0
            while k < len(alphas):
                alpha = alphas[k]
                prev = (prev_traces or {}).get(alpha)
                pos = suffix_start if prev is not None else 0
                counters[0 if pos else 1] += 1
                s, bnd, tr = inst.schedule_traced(
                    queue, alpha, period=period, want_bound=True,
                    resume=prev, resume_pos=pos, backend=backend,
                    batch=batch)
                traces[alpha] = tr
                points.append((alpha, s.makespan))
                # analysis: allow[float-arith] strict-improvement epsilon on a reduction over backend outputs, not a per-decision value
                if best is None or s.makespan < best.makespan - 1e-12:
                    best, best_alpha = s, alpha
                k += 1
                # identical decision trace => identical schedule
                # analysis: allow[float-arith] trace-invariance skip bound; margin only widens the re-evaluated alpha set, never changes a schedule
                while k < len(alphas) and alphas[k] < bnd - _SKIP_MARGIN:
                    points.append((alphas[k], s.makespan))
                    k += 1
            return best, best_alpha

        points: List[Tuple[float, float]] = []
        if policy.sweep == "grid":
            alphas = [k * policy.alpha_step for k in range(n_steps + 1)]
            best, best_alpha = grid_pass(alphas, points, None, 0.0)
        else:                                  # adaptive coarse-to-fine
            step, cf = policy.alpha_step, max(1, policy.coarse_factor)
            coarse = [k * step for k in range(0, n_steps + 1, cf)]
            if coarse[-1] != n_steps * step:
                coarse.append(n_steps * step)
            best, best_alpha = grid_pass(coarse, points, None, 0.0)
            assert best is not None
            # refine around every coarse point within the policy's band
            cutoff = best.makespan * policy.refine_within
            refine: set = set()
            for a, m in points:
                if m <= cutoff:
                    ka = int(round(a / step))
                    refine.update(range(max(0, ka - cf),
                                        min(n_steps, ka + cf) + 1))
            done = {round(a, 12) for a, _ in points}
            fine = [k * step for k in sorted(refine)
                    if round(k * step, 12) not in done]
            best, best_alpha = grid_pass(fine, points, best, best_alpha)
            points.sort()
        assert best is not None
        return (SweepResult.from_points(best, best_alpha, points),
                counters[0], counters[1])

    def _sweep_reference(self, sess: _GraphSession, queue: Sequence[int],
                         policy: HVLB_CC_A, period: float) -> SweepResult:
        g, tg = sess.g, self.topology
        n_steps = int(round(policy.alpha_max / policy.alpha_step))
        best: Optional[Schedule] = None
        best_alpha = 0.0
        points: List[Tuple[float, float]] = []
        for k in range(n_steps + 1):
            alpha = k * policy.alpha_step
            s = list_schedule(g, tg, queue, sess.rank, alpha=alpha,
                              period=period, ldet=sess.ldet)
            points.append((alpha, s.makespan))
            # analysis: allow[float-arith] same strict-improvement epsilon as the session sweep (deprecated shim must stay bit-identical)
            if best is None or s.makespan < best.makespan - 1e-12:
                best, best_alpha = s, alpha
        assert best is not None
        return SweepResult.from_points(best, best_alpha, points)
