"""Once-per-process deprecation warnings for the legacy entry points.

The PR-2 shims used to ``warnings.warn`` on *every* call, which turns a
tight benchmark or sweep loop into hundreds of identical lines even
under the default warning filters (each ``stacklevel`` call site counts
as a new location).  :func:`warn_once` emits one real
``DeprecationWarning`` per key per process — loud enough to notice,
quiet enough to keep using the shim while migrating.

``reset()`` clears the emitted set so tests can assert the warning
deterministically (see ``tests/test_deprecation.py``).
"""
from __future__ import annotations

import warnings
from typing import Set

_emitted: Set[str] = set()


def warn_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning`` for ``key`` only the first time."""
    if key in _emitted:
        return
    _emitted.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset() -> None:
    """Forget which warnings fired (test isolation helper)."""
    _emitted.clear()
