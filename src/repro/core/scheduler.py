"""Processor-selection + scheduling phase (Sections 4.2-4.3).

Tasks are dequeued in HPRV order and placed on the processor minimizing the
selection value; their incoming messages are simultaneously scheduled onto
concrete links of a concrete route with contention (scalar per-link
availability — the bus semantics of the paper): Eqs. 10-15.

Selection values:
  HSV_CC  = EFT * LDET_CC                        (baseline, Xie et al. [25])
  HVLB_CC = EFT * LDET_CC * BP(p, alpha)         (Def. 4.2; exits use EFT only)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import SPG
from .ranks import ldet_cc
from .topology import Route, Topology


class SchedulingFailure(Exception):
    """Raised when a task is dequeued before one of its predecessors was
    scheduled — the failure mode of Section 3.2 / Experiment 4."""


@dataclasses.dataclass
class MessagePlacement:
    edge: Tuple[int, int]
    src_proc: int
    dst_proc: int
    route: Route
    # per-link (start, finish) in route order: LST/LFT of Eqs. 13-14
    intervals: List[Tuple[str, float, float]]

    @property
    def lft(self) -> float:
        return self.intervals[-1][2]

    @property
    def lst(self) -> float:
        return self.intervals[0][1]


@dataclasses.dataclass
class Schedule:
    graph: SPG
    topology: Topology
    proc: np.ndarray            # task -> processor
    start: np.ndarray           # task -> AST
    finish: np.ndarray          # task -> AFT
    messages: Dict[Tuple[int, int], MessagePlacement]
    alpha: Optional[float] = None

    @property
    def makespan(self) -> float:
        return float(self.finish.max())

    def tasks_on(self, p: int) -> List[int]:
        order = [i for i in range(self.graph.n) if self.proc[i] == p]
        return sorted(order, key=lambda i: self.start[i])

    def link_intervals(self) -> Dict[str, List[Tuple[float, float, Tuple[int, int]]]]:
        out: Dict[str, List[Tuple[float, float, Tuple[int, int]]]] = {}
        for e, m in self.messages.items():
            for (l, s, f) in m.intervals:
                out.setdefault(l, []).append((s, f, e))
        for l in out:
            out[l].sort()
        return out

    def proc_loads(self) -> np.ndarray:
        """Cumulative computation time per processor (Eq. 25 numerator)."""
        loads = np.zeros(self.topology.n_procs)
        for i in range(self.graph.n):
            loads[self.proc[i]] += self.finish[i] - self.start[i]
        return loads

    def validate(self) -> None:
        """Assert the schedule invariants (used by the property tests)."""
        g, tg = self.graph, self.topology
        eps = 1e-9
        for i in range(g.n):
            assert self.finish[i] >= self.start[i] - eps
            expected = g.comp(i, int(self.proc[i]), tg.rates)
            assert abs((self.finish[i] - self.start[i]) - expected) < 1e-6, \
                f"task {i} duration mismatch"
        # no overlap per processor
        for p in range(tg.n_procs):
            ts = self.tasks_on(p)
            for a, b in zip(ts, ts[1:]):
                assert self.start[b] >= self.finish[a] - eps, \
                    f"tasks {a},{b} overlap on p{p}"
        # precedence + message timing
        for (i, j) in g.edges:
            if self.proc[i] == self.proc[j]:
                assert self.start[j] >= self.finish[i] - eps
            else:
                m = self.messages[(i, j)]
                assert m.lst >= self.finish[i] - eps
                assert self.start[j] >= m.lft - eps
        # no overlap per link
        for l, ivs in self.link_intervals().items():
            for (s1, f1, _), (s2, f2, _) in zip(ivs, ivs[1:]):
                assert s2 >= f1 - eps, f"messages overlap on {l}"


# ----------------------------------------------------------------------
def _route_message(g: SPG, tg: Topology, i: int, j: int, src: int, dst: int,
                   aft_i: float, link_free: Dict[str, float],
                   ) -> MessagePlacement:
    """Schedule message e_{i,j} on the best route src->dst (Eqs. 13-15).

    Wormhole-style pipelining exactly as the recurrences state: the message
    may start on link x+1 as soon as both that link is free and it has
    started on link x; per-link finish is monotone (Eq. 14's outer max).
    Among the available routes the one with the earliest arrival (final LFT)
    wins; ties prefer fewer hops then route order.
    """
    comp_src = g.comp(i, src, tg.rates)
    tpl = g.comm_volume(i, j, comp_src)
    best: Optional[MessagePlacement] = None
    best_key: Tuple[float, int, int] = (np.inf, 0, 0)
    for ridx, route in enumerate(tg.routes[(src, dst)]):
        intervals: List[Tuple[str, float, float]] = []
        lst_prev = None
        lft_prev = 0.0
        for l in route:
            avail = link_free.get(l, 0.0)
            if lst_prev is None:
                lst = max(aft_i, avail)                      # Eq. 13 (first)
            else:
                lst = max(lst_prev, avail)                   # Eq. 13 (next)
            ctml = tg.ctml(tpl, l)                           # Eq. 15
            lft = max(lft_prev, lst + ctml)                  # Eq. 14
            intervals.append((l, lst, lft))
            lst_prev, lft_prev = lst, lft
        key = (lft_prev, len(route), ridx)
        if key < best_key:
            best_key = key
            best = MessagePlacement((i, j), src, dst, route, intervals)
    assert best is not None
    return best


@dataclasses.dataclass
class _Candidate:
    proc: int
    est: float
    eft: float
    value: float
    msgs: List[MessagePlacement]


def _evaluate(g: SPG, tg: Topology, j: int, p: int, rank: np.ndarray,
              ldet: np.ndarray, proc_free: np.ndarray,
              link_free: Dict[str, float], aft: np.ndarray,
              proc_of: np.ndarray, bp: float) -> _Candidate:
    """EST/EFT (Eqs. 10-12) and the selection value for candidate p."""
    msgs: List[MessagePlacement] = []
    tentative = dict(link_free)
    arrival = 0.0
    # schedule this task's incoming messages in message-ready order
    for i in sorted(g.pred[j], key=lambda i: (aft[i], i)):
        src = int(proc_of[i])
        if src == p:
            arrival = max(arrival, aft[i])
            continue
        m = _route_message(g, tg, i, j, src, p, aft[i], tentative)
        for (l, s, f) in m.intervals:
            tentative[l] = max(tentative.get(l, 0.0), f)
        msgs.append(m)
        arrival = max(arrival, m.lft)
    est = max(proc_free[p], arrival)                         # Eqs. 10-11
    eft = est + g.comp(j, p, tg.rates)                       # Eq. 12
    if not g.succ[j]:                                        # exit task
        value = eft                                          # Def. 4.2
    else:
        value = eft * ldet[j, p] * bp
    return _Candidate(p, est, eft, value, msgs)


def list_schedule(g: SPG, tg: Topology, queue: Sequence[int],
                  rank: np.ndarray, alpha: float = 0.0,
                  period: Optional[float] = None,
                  bp_on_exit: bool = True,
                  ldet: Optional[np.ndarray] = None) -> Schedule:
    """Run the processor-selection phase for a given priority queue.

    ``alpha == 0`` makes BP == 1 everywhere and the algorithm *is* HSV_CC.
    ``period`` defaults to the sum of min computation times of the graph
    (the DAG's deadline proxy; Definition 4.1 normalizes processor load by
    the application period).  ``ldet`` may be passed in to share the Eq. 16
    matrix across repeated calls (the alpha sweep); it defaults to
    ``ldet_cc(g, tg, rank)``.

    This is the readable reference implementation; the compiled engine in
    :mod:`repro.core.engine` reproduces it bit-for-bit on flat arrays.
    """
    P = tg.n_procs
    if ldet is None:
        ldet = ldet_cc(g, tg, rank)
    if period is None:
        period = g.default_period(tg.rates, P)
    proc_free = np.zeros(P)
    link_free: Dict[str, float] = {}
    proc_of = np.full(g.n, -1, dtype=int)
    ast = np.zeros(g.n)
    aft = np.zeros(g.n)
    loads = np.zeros(P)           # cumulative comp time per processor
    messages: Dict[Tuple[int, int], MessagePlacement] = {}
    scheduled = np.zeros(g.n, dtype=bool)

    for j in queue:
        for i in g.pred[j]:
            if not scheduled[i]:
                raise SchedulingFailure(
                    f"task {j} dequeued before predecessor {i} (Sec. 3.2)")
        best: Optional[_Candidate] = None
        for p in range(P):
            bp = 1.0 + (loads[p] / period) * alpha           # Def. 4.1
            cand = _evaluate(g, tg, j, p, rank, ldet, proc_free,
                             link_free, aft, proc_of, bp)
            if best is None or (cand.value, cand.eft, cand.proc) < \
                    (best.value, best.eft, best.proc):
                best = cand
        assert best is not None
        p = best.proc
        proc_of[j] = p
        ast[j], aft[j] = best.est, best.eft
        proc_free[p] = best.eft
        loads[p] += g.comp(j, p, tg.rates)
        for m in best.msgs:
            messages[m.edge] = m
            for (l, s, f) in m.intervals:
                link_free[l] = max(link_free.get(l, 0.0), f)
        scheduled[j] = True

    return Schedule(g, tg, proc_of, ast, aft, messages, alpha=alpha)
