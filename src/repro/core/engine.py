"""Compiled scheduling engine: array-based core for the Eq. 10-15 loop.

``list_schedule`` in :mod:`.scheduler` is the readable reference: every
candidate evaluation copies a string-keyed ``link_free`` dict, re-walks
route tuples through method calls, and allocates a ``MessagePlacement``
per route probed.  :class:`CompiledInstance` preprocesses an
``(SPG, Topology)`` pair once —

  * link names interned to integer ids (``Topology.link_index`` order),
  * route tables flattened to ``(link_id, link_speed)`` tuples per
    ``(src, dst)`` pair,
  * per-(edge, source-processor) communication volumes ``tpl(e_ij | p)``,
  * the cached ``(n, P)`` computation matrix, the rank/LDET matrices and
    the default period

— and then runs the selection loop against flat Python lists with
commit/rollback of link state instead of per-candidate dict copies.  Every
floating-point operation is performed in the same order as the reference,
so the produced :class:`~.scheduler.Schedule` is bit-identical (asserted
by ``tests/test_engine_equivalence.py``).

The engine additionally supports *decision-trace interval skipping* for
the HVLB_CC alpha sweep (Algorithm 1).  Along a fixed trace (sequence of
chosen processors) every candidate's selection value is linear in alpha:

    value_p(a) = A_p + B_p * a,   A_p = EFT_p * LDET_p,
                                  B_p = A_p * load_p / period

so after simulating one alpha the engine reports the supremum alpha up to
which every decision's winner provably keeps winning
(:meth:`CompiledInstance.schedule_with_bound`).  Grid points strictly
inside that interval reuse the simulated schedule without re-running the
selection loop — consecutive alphas that would pick the same processor
sequence skip re-simulation entirely.

Finally the engine supports *decision-trace suffix replay* for the online
rescheduling loop (:mod:`repro.core.api`).  :meth:`schedule_traced`
records every committed decision — chosen processor, EST/EFT, the
winner's message placements, and (when bound tracking) the per-candidate
``(A_p, B_p)`` linear coefficients.  A later call may *resume* from such
a trace: the first ``resume_pos`` positions are re-committed from the
record (cheap state application, no candidate evaluation — the same
floating-point commits in the same order, so the rebuilt link/processor
state is bit-identical), and the full selection loop runs only for the
suffix.  The caller is responsible for proving the prefix unchanged
(see ``api.Scheduler.update``); the engine asserts the cheap
consistency conditions (same alpha/period/queue prefix).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import SPG
from .ranks import ldet_cc, rank_matrix
from .scheduler import MessagePlacement, Schedule, SchedulingFailure
from .topology import Topology

_INF = float("inf")


# One committed decision: (task, proc, est, eft, msgs, cand_A, cand_B).
# ``msgs`` is the winner's [(pred, route, [(link_id, lst, lft), ...]), ...];
# cand_A/cand_B are P-tuples of the linear selection coefficients (None for
# exit tasks or when the run did not track the alpha bound).
DecisionRecord = Tuple[int, int, float, float, list, Optional[tuple],
                       Optional[tuple]]


@dataclasses.dataclass
class DecisionTrace:
    """Memoized decision sequence of one :meth:`CompiledInstance._run`.

    Replayable: committing ``records[:k]`` reconstructs the exact engine
    state after the first ``k`` dequeues, so an update whose first ``k``
    decisions are provably unchanged re-simulates only positions ``k..n``.
    """

    queue: Tuple[int, ...]
    alpha: float
    period: float
    want_bound: bool
    records: List[DecisionRecord]


class CompiledInstance:
    """One-time preprocessing of an ``(SPG, Topology)`` pair.

    Build once, then call :meth:`schedule` (or
    :meth:`schedule_with_bound`) any number of times — the alpha sweep,
    online re-planning, and the throughput benchmarks all share the same
    instance.
    """

    def __init__(self, g: SPG, tg: Topology,
                 rank: Optional[np.ndarray] = None,
                 ldet: Optional[np.ndarray] = None) -> None:
        self.g, self.tg = g, tg
        self.P = P = tg.n_procs
        self.n = g.n

        comp = g.comp_matrix_for(tg.rates)
        self.comp = comp
        self._comp = comp.tolist()
        self.rank = rank_matrix(g, tg) if rank is None else rank
        self.ldet = ldet_cc(g, tg, self.rank) if ldet is None else ldet
        self._ldet = self.ldet.tolist()
        self.default_period = g.default_period(tg.rates, P)

        self._link_names = tg.all_links()
        self._n_links = len(self._link_names)
        link_id = tg.link_index()
        # (src, dst) -> [(link_ids, link_speeds, route_tuple), ...] in the
        # reference's route order (ties prefer fewer hops then route index).
        self._routes: Dict[Tuple[int, int], List[
            Tuple[Tuple[int, ...], Tuple[float, ...], Tuple[str, ...]]]] = {}
        for pair, rr in tg.routes.items():
            self._routes[pair] = [
                (tuple(link_id[l] for l in r),
                 tuple(float(tg.link_speed[l]) for l in r),
                 r) for r in rr]
        # tpl(e_ij | p_src) per edge; constant over p unless the graph uses
        # the worked-example CCR-proportional convention.
        self._tpl: Dict[Tuple[int, int], List[float]] = {
            (i, j): [g.comm_volume(i, j, self._comp[i][p]) for p in range(P)]
            for (i, j) in g.edges}
        self._preds: List[List[int]] = [list(g.pred[j]) for j in range(g.n)]
        self._is_exit: List[bool] = [not g.succ[j] for j in range(g.n)]
        self._ctml_mode = tg.ctml_mode
        # (i, j, src, dst) -> [(link_ids, ctml_per_hop, route), ...]:
        # CTML (Eq. 15, incl. quantization) is static per edge/route, so it
        # is computed once on first use and reused by every later candidate
        # evaluation, alpha step, and re-plan.
        self._msg_plans: Dict[Tuple[int, int, int, int], List[
            Tuple[Tuple[int, ...], Tuple[float, ...],
                  Tuple[str, ...]]]] = {}
        # Decision-replay accounting (read by api.Scheduler / the tests):
        # positions evaluated with the full candidate loop vs positions
        # re-committed from a memoized trace.
        self.n_decisions_simulated = 0
        self.n_decisions_replayed = 0

    # ------------------------------------------------------------------
    def schedule(self, queue: Sequence[int], alpha: float = 0.0,
                 period: Optional[float] = None) -> Schedule:
        """Array-core equivalent of :func:`~.scheduler.list_schedule`."""
        s, _, _ = self._run(queue, alpha, period, want_bound=False)
        return s

    def schedule_with_bound(self, queue: Sequence[int], alpha: float,
                            period: Optional[float] = None
                            ) -> Tuple[Schedule, float]:
        """Schedule at ``alpha`` and return ``(schedule, bound)`` where the
        decision trace — hence the schedule — is provably unchanged for
        every ``alpha' in [alpha, bound)``."""
        s, bound, _ = self._run(queue, alpha, period, want_bound=True)
        return s, bound

    def schedule_traced(self, queue: Sequence[int], alpha: float = 0.0,
                        period: Optional[float] = None,
                        want_bound: bool = True,
                        resume: Optional[DecisionTrace] = None,
                        resume_pos: int = 0
                        ) -> Tuple[Schedule, float, DecisionTrace]:
        """Schedule and memoize the decision trace.

        With ``resume``/``resume_pos`` the first ``resume_pos`` decisions
        are re-committed from the given trace instead of re-evaluated —
        the suffix-replay primitive behind :meth:`api.Scheduler.update`.
        The caller must guarantee the prefix decisions are unchanged
        (same comp/LDET rows, message volumes, and queue prefix); the
        result is then bit-identical to a from-scratch run.
        """
        return self._run(queue, alpha, period, want_bound=want_bound,
                         record=True, resume=resume, resume_pos=resume_pos)

    # ------------------------------------------------------------------
    def _run(self, queue: Sequence[int], alpha: float,
             period: Optional[float], want_bound: bool,
             record: bool = False,
             resume: Optional[DecisionTrace] = None,
             resume_pos: int = 0
             ) -> Tuple[Schedule, float, Optional[DecisionTrace]]:
        g, tg = self.g, self.tg
        P = self.P
        comp = self._comp
        ldet = self._ldet
        tpl_table = self._tpl
        routes = self._routes
        msg_plans = self._msg_plans
        preds_of = self._preds
        is_exit = self._is_exit
        names = self._link_names
        mode = self._ctml_mode
        quant_round = mode == "round"
        quant_ceil = mode == "ceil"
        if period is None:
            period = self.default_period

        link_free = [0.0] * self._n_links
        proc_free = [0.0] * P
        proc_of = [-1] * self.n
        ast = [0.0] * self.n
        aft = [0.0] * self.n
        loads = [0.0] * P
        scheduled = [False] * self.n
        messages: Dict[Tuple[int, int], MessagePlacement] = {}
        bound = _INF
        cand_A = [0.0] * P
        cand_B = [0.0] * P
        records: List[DecisionRecord] = []

        start = 0
        if resume is not None and resume_pos > 0:
            if resume.alpha != alpha or resume.want_bound != want_bound \
                    or resume.period != period:
                raise ValueError("resume trace was recorded under different "
                                 "(alpha, period, bound-tracking) settings")
            if tuple(queue[:resume_pos]) != resume.queue[:resume_pos]:
                raise ValueError("resume trace queue prefix mismatch")
            start = resume_pos
            # Re-commit the memoized prefix: the same floating-point state
            # updates in the same order as the original run — no candidate
            # evaluation, no route walks.
            for rec in resume.records[:resume_pos]:
                j, p, est, eft, msgs, ca, cb = rec
                proc_of[j] = p
                ast[j] = est
                aft[j] = eft
                proc_free[p] = eft
                loads[p] += comp[j][p]
                for (i, route, iv) in msgs:
                    messages[(i, j)] = MessagePlacement(
                        (i, j), proc_of[i], p, route,
                        [(names[lid], s_, f) for (lid, s_, f) in iv])
                    for (lid, _s, f) in iv:
                        if f > link_free[lid]:
                            link_free[lid] = f
                scheduled[j] = True
                if want_bound and ca is not None:
                    # same crossing-point arithmetic as the live loop below,
                    # on the memoized candidate coefficients
                    a_c, b_c = ca[p], cb[p]
                    for r in range(P):
                        if r == p:
                            continue
                        d_b = b_c - cb[r]
                        d_a = ca[r] - a_c
                        scale = abs(a_c) + abs(ca[r]) + 1.0
                        if d_b > 1e-15 * scale:
                            a_star = d_a / d_b
                            if a_star < bound:
                                bound = a_star
                        elif abs(d_b) <= 1e-15 * scale and \
                                abs(d_a) <= 1e-12 * scale:
                            if alpha < bound:
                                bound = alpha
                if record:
                    records.append(rec)
            self.n_decisions_replayed += resume_pos

        sim_count = 0
        for j in queue[start:] if start else queue:
            sim_count += 1
            preds = preds_of[j]
            for i in preds:
                if not scheduled[i]:
                    raise SchedulingFailure(
                        f"task {j} dequeued before predecessor {i} (Sec. 3.2)")
            order = sorted(preds, key=lambda i: (aft[i], i))
            comp_j = comp[j]
            ldet_j = ldet[j]
            exit_j = is_exit[j]
            track = want_bound and not exit_j
            best_value = best_eft = 0.0
            best_est = 0.0
            best_p = -1
            best_msgs: List[Tuple[int, Tuple[str, ...],
                                  List[Tuple[int, float, float]]]] = []

            for p in range(P):
                arrival = 0.0
                msgs: List[Tuple[int, Tuple[str, ...],
                                 List[Tuple[int, float, float]]]] = []
                touched: List[Tuple[int, float]] = []
                for i in order:
                    src = proc_of[i]
                    if src == p:
                        if aft[i] > arrival:
                            arrival = aft[i]
                        continue
                    aft_i = aft[i]
                    plans = msg_plans.get((i, j, src, p))
                    if plans is None:
                        tpl = tpl_table[(i, j)][src]
                        plans = []
                        for (lids, spds, robj) in routes[(src, p)]:
                            cts = []
                            for sp in spds:
                                t = tpl / sp                     # Eq. 15
                                if quant_round:
                                    t = float(round(t))
                                elif quant_ceil:
                                    t = float(np.ceil(t))
                                cts.append(t)
                            plans.append((lids, tuple(cts), robj))
                        msg_plans[(i, j, src, p)] = plans
                    # --- best route src -> p (Eqs. 13-15) ---
                    bk0, bk1, bk2 = _INF, 0, 0
                    best_iv: Optional[List[Tuple[int, float, float]]] = None
                    best_route: Tuple[str, ...] = ()
                    for ridx, (lids, cts, robj) in enumerate(plans):
                        iv: List[Tuple[int, float, float]] = []
                        first = True
                        lst = 0.0
                        lft = 0.0
                        for h in range(len(lids)):
                            lid = lids[h]
                            avail = link_free[lid]
                            if first:
                                lst = aft_i if aft_i > avail else avail
                                first = False
                            else:
                                lst = lst if lst > avail else avail
                            x = lst + cts[h]
                            lft = lft if lft > x else x          # Eq. 14
                            iv.append((lid, lst, lft))
                        nh = len(lids)
                        if lft < bk0 or (lft == bk0 and
                                         (nh < bk1 or (nh == bk1 and
                                                       ridx < bk2))):
                            bk0, bk1, bk2 = lft, nh, ridx
                            best_iv = iv
                            best_route = robj
                    assert best_iv is not None
                    for (lid, _s, f) in best_iv:
                        old = link_free[lid]
                        touched.append((lid, old))
                        if f > old:
                            link_free[lid] = f
                    msgs.append((i, best_route, best_iv))
                    if bk0 > arrival:
                        arrival = bk0
                pf = proc_free[p]
                est = pf if pf > arrival else arrival            # Eqs. 10-11
                eft = est + comp_j[p]                            # Eq. 12
                if exit_j:
                    value = eft                                  # Def. 4.2
                else:
                    bp = 1.0 + (loads[p] / period) * alpha       # Def. 4.1
                    value = eft * ldet_j[p] * bp
                for lid, old in reversed(touched):
                    link_free[lid] = old
                if track:
                    a_p = eft * ldet_j[p]
                    cand_A[p] = a_p
                    cand_B[p] = a_p * (loads[p] / period)
                if best_p < 0 or value < best_value or \
                        (value == best_value and eft < best_eft):
                    # strict lexicographic (value, eft, proc): p ascends,
                    # so an exact (value, eft) tie keeps the earlier proc
                    best_value, best_eft, best_est = value, eft, est
                    best_p, best_msgs = p, msgs

            p = best_p
            proc_of[j] = p
            ast[j] = best_est
            aft[j] = best_eft
            proc_free[p] = best_eft
            loads[p] += comp_j[p]
            for (i, route, iv) in best_msgs:
                messages[(i, j)] = MessagePlacement(
                    (i, j), proc_of[i], p, route,
                    [(names[lid], s_, f) for (lid, s_, f) in iv])
                for (lid, _s, f) in iv:
                    if f > link_free[lid]:
                        link_free[lid] = f
            scheduled[j] = True
            if track:
                a_c, b_c = cand_A[p], cand_B[p]
                for r in range(P):
                    if r == p:
                        continue
                    d_b = b_c - cand_B[r]
                    d_a = cand_A[r] - a_c
                    scale = abs(a_c) + abs(cand_A[r]) + 1.0
                    if d_b > 1e-15 * scale:
                        a_star = d_a / d_b
                        if a_star < bound:
                            bound = a_star
                    elif abs(d_b) <= 1e-15 * scale and \
                            abs(d_a) <= 1e-12 * scale:
                        # numerically indistinguishable rival: prediction
                        # is unreliable, force re-simulation next step
                        if alpha < bound:
                            bound = alpha
            if record:
                records.append((j, p, best_est, best_eft, best_msgs,
                                tuple(cand_A) if track else None,
                                tuple(cand_B) if track else None))

        self.n_decisions_simulated += sim_count
        trace = DecisionTrace(tuple(queue), alpha,
                              period, want_bound, records) if record else None
        return Schedule(g, tg, np.array(proc_of), np.array(ast),
                        np.array(aft), messages, alpha=alpha), bound, trace
