"""Compiled scheduling engine: the decision layer of the Eq. 10-15 loop.

``list_schedule`` in :mod:`.scheduler` is the readable reference: every
candidate evaluation copies a string-keyed ``link_free`` dict, re-walks
route tuples through method calls, and allocates a ``MessagePlacement``
per route probed.  :class:`CompiledInstance` preprocesses an
``(SPG, Topology)`` pair once —

  * link names interned to integer ids (``Topology.link_index`` order),
  * route tables flattened to ``(link_id, link_speed)`` tuples per
    ``(src, dst)`` pair,
  * per-(edge, source-processor) communication volumes ``tpl(e_ij | p)``,
  * the cached ``(n, P)`` computation matrix, the rank/LDET matrices and
    the default period

— and runs the selection loop on top of a pluggable **candidate
evaluation backend** (:mod:`repro.core.backends`).  The engine itself is
the *decision layer*: queue walk, precedence checks, decision-trace
recording/replay, and :class:`~.scheduler.Schedule` assembly.  The
queue walk is **level-batched**: a *wave* is a maximal run of
consecutive queue entries carrying no precedence edge into the wave —
tasks sharing a rank level (the paper's longest entry->node depth,
which every edge strictly increases) are the canonical case — and each
wave is handed to the backend whole via ``evaluate_batch``.  The
HVLB_CC (B) priority order is approximately level-sorted, so a
schedule decomposes into O(levels) waves.  Decisions are *batch-invariant* (waves still evaluate
and commit sequentially inside the backend; batching only moves the
loop), which is what lets a device backend run a whole wave in a single
kernel launch with one host round-trip per wave instead of per
decision.  The *numeric layer* — per-task evaluation of all P placement
candidates, including the sequential message-routing walks with
commit/rollback link state — is a
:class:`~repro.core.backends.CandidateEvaluator`:
``"scalar"`` (flat Python lists, the bit-exactness reference),
``"vector"`` ((P,)-batch NumPy ops, the P >= 8 fast path), or
``"pallas"`` (opt-in JAX/Pallas device kernel, interpret mode on CPU);
``backend="auto"`` resolves per instance.  The NumPy backends perform
IEEE operations whose results are bit-identical to the reference, so
the produced :class:`~.scheduler.Schedule` is too; the pallas backend
is held decision-identical (asserted by
``tests/test_engine_equivalence.py`` and
``tests/test_backend_equivalence.py``).

The engine additionally supports *decision-trace interval skipping* for
the HVLB_CC alpha sweep (Algorithm 1).  Along a fixed trace (sequence of
chosen processors) every candidate's selection value is linear in alpha:

    value_p(a) = A_p + B_p * a,   A_p = EFT_p * LDET_p,
                                  B_p = A_p * load_p / period

so after simulating one alpha the engine reports the supremum alpha up to
which every decision's winner provably keeps winning
(:meth:`CompiledInstance.schedule_with_bound`).  Grid points strictly
inside that interval reuse the simulated schedule without re-running the
selection loop — consecutive alphas that would pick the same processor
sequence skip re-simulation entirely.

Finally the engine supports *decision-trace suffix replay* for the online
rescheduling loop (:mod:`repro.core.api`).  :meth:`schedule_traced`
records every committed decision — chosen processor, EST/EFT, the
winner's message placements, and (when bound tracking) the per-candidate
``(A_p, B_p)`` linear coefficients.  A later call may *resume* from such
a trace: the first ``resume_pos`` positions are re-committed from the
record (cheap state application, no candidate evaluation — the same
floating-point commits in the same order, so the rebuilt link/processor
state is bit-identical), and the full selection loop runs only for the
suffix.  The caller is responsible for proving the prefix unchanged
(see ``api.Scheduler.update``); the engine asserts the cheap
consistency conditions (same alpha/period/queue prefix).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .backends import CandidateEvaluator, backend_class, resolve_backend_name
from .faults import (DOWN_COMP, INFEASIBLE_EFT, FaultSpec,
                     InfeasibleScheduleError)
from .graph import SPG
from .ranks import ldet_cc, rank_matrix
from .scheduler import MessagePlacement, Schedule, SchedulingFailure
from .topology import Topology

_INF = float("inf")

# Default cap on the level-batch size the decision layer hands to
# ``CandidateEvaluator.evaluate_batch`` (``batch=None``).  Decisions are
# batch-invariant — the cap only bounds kernel unroll/staging cost for
# device backends; ``batch=1`` recovers the strict per-decision walk.
DEFAULT_BATCH_MAX = 16


def validate_batch(batch) -> Optional[int]:
    """Validated level-batch cap (``None`` passes through as "default").

    Loud on anything but a genuine int >= 1: a non-integral value must
    not silently truncate to a cap (and a session plan-cache key) the
    caller never asked for.  Single source of truth for the engine and
    the session API.
    """
    if batch is None:
        return None
    if isinstance(batch, bool) or int(batch) != batch or int(batch) < 1:
        raise ValueError(f"batch must be an int >= 1, got {batch!r}")
    return int(batch)


def plan_waves(queue: Sequence[int], preds_of: Sequence[Sequence[int]],
               batch_cap: int) -> List[List[int]]:
    """The level-batched **wave plan** of a queue: maximal runs of
    consecutive queue entries with no precedence edge *into the run*,
    capped at ``batch_cap`` (DESIGN.md §5).

    A pure function of the static structure ``(queue, precedence edges,
    cap)`` — no schedule state — which is what lets the engine emit the
    whole plan up front and hand it to the backend in one
    ``evaluate_plan`` call (the device backend folds the entire plan
    into a single dispatch).  Tasks sharing a rank level are the
    canonical wave; the direct predecessor check also absorbs
    independent tasks of interleaved levels (transitive dependencies
    cannot hide inside a wave: a precedence-safe queue would place the
    intermediate task inside it too).  Decisions are wave-cap-invariant,
    so the plan shape never changes the schedule.
    """
    waves: List[List[int]] = []
    nq = len(queue)
    qi = 0
    while qi < nq:
        wave = set()
        hi = qi
        while hi < nq and hi - qi < batch_cap:
            j = queue[hi]
            if any(i in wave for i in preds_of[j]):
                break                    # depends on the wave: next one
            wave.add(j)
            hi += 1
        waves.append(list(queue[qi:hi]))
        qi = hi
    return waves


# One committed decision:
# (task, proc, est, eft, msgs, cand_A, cand_B, batch_id).
# ``msgs`` is the winner's [(pred, route, [(link_id, lst, lft), ...]), ...];
# cand_A/cand_B are P-tuples of the linear selection coefficients (None for
# exit tasks or when the run did not track the alpha bound).  ``batch_id``
# is the index of the level batch that produced the decision — purely
# informational (decisions are batch-invariant), but recorded so a resumed
# run can keep its batch numbering monotone and the equivalence tests can
# assert identical grouping across backends (pallas <-> scalar resume).
DecisionRecord = Tuple[int, int, float, float, list, Optional[tuple],
                       Optional[tuple], int]


@dataclasses.dataclass
class DecisionTrace:
    """Memoized decision sequence of one :meth:`CompiledInstance._run`.

    Replayable: committing ``records[:k]`` reconstructs the exact engine
    state after the first ``k`` dequeues, so an update whose first ``k``
    decisions are provably unchanged re-simulates only positions ``k..n``.
    """

    queue: Tuple[int, ...]
    alpha: float
    period: float
    want_bound: bool
    records: List[DecisionRecord]


class CompiledInstance:
    """One-time preprocessing of an ``(SPG, Topology)`` pair.

    Build once, then call :meth:`schedule` (or
    :meth:`schedule_with_bound`) any number of times — the alpha sweep,
    online re-planning, and the throughput benchmarks all share the same
    instance.
    """

    def __init__(self, g: SPG, tg: Topology,
                 rank: Optional[np.ndarray] = None,
                 ldet: Optional[np.ndarray] = None,
                 faults: Optional[FaultSpec] = None) -> None:
        self.g, self.tg = g, tg
        self.P = P = tg.n_procs
        self.n = g.n
        # Fault masking (DESIGN.md §6): a down processor's comp column and
        # a faulted link's effective speed are masked with *finite*
        # sentinels right here, so every backend runs its unmodified
        # healthy-path arithmetic and a masked candidate simply carries an
        # EFT beyond the feasibility horizon.  Rank/LDET/queues stay those
        # of the healthy system (priorities are estimates, and freezing
        # them is what keeps the fault-untouched trace prefix replayable).
        if faults is not None and faults.is_empty:
            faults = None
        self.faults = faults
        self.wave_timeout: Optional[float] = None   # engine watchdog (s)

        comp = g.comp_matrix_for(tg.rates)
        if faults is not None and faults.down_procs:
            comp = comp.copy()          # never poison the graph's cache
            comp[:, list(faults.down_procs)] = DOWN_COMP
        self.comp = comp
        self._comp = comp.tolist()
        self.rank = rank_matrix(g, tg) if rank is None else rank
        self.ldet = ldet_cc(g, tg, self.rank) if ldet is None else ldet
        self._ldet = self.ldet.tolist()
        self.default_period = g.default_period(tg.rates, P)

        self._link_names = tg.all_links()
        self._n_links = len(self._link_names)
        link_id = tg.link_index()
        if faults is not None and faults.link_factors:
            def _speed(l: str) -> float:
                return faults.effective_speed(l, float(tg.link_speed[l]))
        else:
            def _speed(l: str) -> float:
                return float(tg.link_speed[l])
        # (src, dst) -> [(link_ids, link_speeds, route_tuple), ...] in the
        # reference's route order (ties prefer fewer hops then route index).
        # Speeds are the fault-effective ones; backends/layout.py reads
        # them from here, so one masking point covers every backend.
        self._routes: Dict[Tuple[int, int], List[
            Tuple[Tuple[int, ...], Tuple[float, ...], Tuple[str, ...]]]] = {}
        for pair, rr in tg.routes.items():
            self._routes[pair] = [
                (tuple(link_id[l] for l in r),
                 tuple(_speed(l) for l in r),
                 r) for r in rr]
        # tpl(e_ij | p_src) per edge; constant over p unless the graph uses
        # the worked-example CCR-proportional convention.
        self._tpl: Dict[Tuple[int, int], List[float]] = {
            (i, j): [g.comm_volume(i, j, self._comp[i][p]) for p in range(P)]
            for (i, j) in g.edges}
        self._preds: List[List[int]] = [list(g.pred[j]) for j in range(g.n)]
        self._is_exit: List[bool] = [not g.succ[j] for j in range(g.n)]
        self._ctml_mode = tg.ctml_mode
        # (i, j, src, dst) -> [(link_ids, ctml_per_hop, route), ...]:
        # CTML (Eq. 15, incl. quantization) is static per edge/route, so it
        # is computed once on first use and reused by every later candidate
        # evaluation, alpha step, and re-plan.
        self._msg_plans: Dict[Tuple[int, int, int, int], List[
            Tuple[Tuple[int, ...], Tuple[float, ...],
                  Tuple[str, ...]]]] = {}
        # Decision-replay accounting (read by api.Scheduler / the tests):
        # positions evaluated with the full candidate loop vs positions
        # re-committed from a memoized trace.
        self.n_decisions_simulated = 0
        self.n_decisions_replayed = 0
        # candidate-evaluation backends, built lazily per name
        self._backends: Dict[str, CandidateEvaluator] = {}
        # per-source-processor route-tensor layouts (backends/layout.py),
        # shared by every array backend and every edge of this instance,
        # plus the (E, P) tpl matrix / edge interning the all-edge CTML
        # precompilation indexes by
        self._src_layouts: Dict[int, object] = {}
        self._edge_index: Dict[Tuple[int, int], int] = {
            e: k for k, e in enumerate(g.edges)}
        self._tpl_matrix = np.array(
            [self._tpl[e] for e in g.edges]).reshape(len(g.edges), P)

    # ------------------------------------------------------------------
    def msg_plans_for(self, i: int, j: int, src: int, dst: int) -> list:
        """Cached per-route ``(link_ids, CTMLs, route_names)`` for message
        ``e_ij`` travelling ``src -> dst`` — the scalar backend's Eq. 15
        CTML source.  The array backends quantize the same values
        vectorized in ``backends/layout.py`` (``ensure_ct_table``);
        the two code paths must stay elementwise bit-identical — change
        quantization in BOTH or ``tests/test_backend_equivalence.py``
        will say so."""
        key = (i, j, src, dst)
        plans = self._msg_plans.get(key)
        if plans is None:
            tpl = self._tpl[(i, j)][src]
            quant_round = self._ctml_mode == "round"
            quant_ceil = self._ctml_mode == "ceil"
            plans = []
            for (lids, spds, robj) in self._routes[(src, dst)]:
                cts = []
                for sp in spds:
                    t = tpl / sp                             # Eq. 15
                    if quant_round:
                        t = float(round(t))
                    elif quant_ceil:
                        t = float(np.ceil(t))
                    cts.append(t)
                plans.append((lids, tuple(cts), robj))
            self._msg_plans[key] = plans
        return plans

    # ------------------------------------------------------------------
    def backend_instance(self, backend: Optional[str] = None
                         ) -> CandidateEvaluator:
        """The (cached) evaluator for a backend name; ``None``/``"auto"``
        resolve via :func:`repro.core.backends.resolve_backend_name`."""
        name = resolve_backend_name(backend, self.P, self.tg)
        be = self._backends.get(name)
        if be is None:
            be = backend_class(name)(self)
            self._backends[name] = be
        return be

    # ------------------------------------------------------------------
    def schedule(self, queue: Sequence[int], alpha: float = 0.0,
                 period: Optional[float] = None,
                 backend: Optional[str] = None,
                 batch: Optional[int] = None) -> Schedule:
        """Array-core equivalent of :func:`~.scheduler.list_schedule`.

        ``batch`` caps the level-batch size handed to the backend's
        ``evaluate_batch`` (``None`` = :data:`DEFAULT_BATCH_MAX`, ``1`` =
        strict per-decision walk).  Decisions are batch-invariant; the
        knob trades kernel-launch amortization against staging size on
        device backends and is a no-op for scalar/vector.
        """
        s, _, _ = self._run(queue, alpha, period, want_bound=False,
                            backend=backend, batch=batch)
        return s

    def schedule_with_bound(self, queue: Sequence[int], alpha: float,
                            period: Optional[float] = None,
                            backend: Optional[str] = None,
                            batch: Optional[int] = None
                            ) -> Tuple[Schedule, float]:
        """Schedule at ``alpha`` and return ``(schedule, bound)`` where the
        decision trace — hence the schedule — is provably unchanged for
        every ``alpha' in [alpha, bound)``."""
        s, bound, _ = self._run(queue, alpha, period, want_bound=True,
                                backend=backend, batch=batch)
        return s, bound

    def schedule_traced(self, queue: Sequence[int], alpha: float = 0.0,
                        period: Optional[float] = None,
                        want_bound: bool = True,
                        resume: Optional[DecisionTrace] = None,
                        resume_pos: int = 0,
                        backend: Optional[str] = None,
                        batch: Optional[int] = None
                        ) -> Tuple[Schedule, float, DecisionTrace]:
        """Schedule and memoize the decision trace.

        With ``resume``/``resume_pos`` the first ``resume_pos`` decisions
        are re-committed from the given trace instead of re-evaluated —
        the suffix-replay primitive behind :meth:`api.Scheduler.update`.
        The caller must guarantee the prefix decisions are unchanged
        (same comp/LDET rows, message volumes, and queue prefix); the
        result is then bit-identical to a from-scratch run.  Traces are
        backend-portable: records hold plain floats and committing them
        is backend-shared scalar code, so a trace recorded under one
        backend resumes bit-identically under another.
        """
        return self._run(queue, alpha, period, want_bound=want_bound,
                         record=True, resume=resume, resume_pos=resume_pos,
                         backend=backend, batch=batch)

    # -------------------------------------------------------- fused sweep
    def sweep_supported(self, backend: Optional[str] = None) -> bool:
        """Whether :meth:`schedule_sweep` can run on this backend — i.e.
        the resolved evaluator fuses whole alpha grids into one dispatch
        (``CandidateEvaluator.supports_plan_sweep``)."""
        try:
            return self.backend_instance(backend).supports_plan_sweep()
        except Exception:
            return False

    def schedule_sweep(self, queue: Sequence[int], alphas: Sequence[float],
                       period: Optional[float] = None,
                       backend: Optional[str] = None,
                       batch: Optional[int] = None
                       ) -> List[Tuple[Schedule, float, DecisionTrace]]:
        """Schedule one queue under **every** alpha of a grid in a single
        device dispatch (the (A, B) fused sweep, DESIGN.md §5).

        Per-alpha results are identical to ``len(alphas)`` independent
        :meth:`schedule_traced` calls with ``want_bound=True`` — same
        decisions, same recorded traces (so a later ``update()`` resumes
        from them exactly like host-loop sweep traces), same
        :class:`~.faults.InfeasibleScheduleError` on the first infeasible
        (alpha, task) in sweep order.  Only valid when
        :meth:`sweep_supported`; fresh runs only (resume goes through the
        per-alpha host loop, which replays prefixes per trace).
        """
        g, tg = self.g, self.tg
        preds_of = self._preds
        names = self._link_names
        if period is None:
            period = self.default_period
        batch_cap = validate_batch(batch)
        if batch_cap is None:
            batch_cap = DEFAULT_BATCH_MAX
        be = self.backend_instance(backend)
        be.start(alphas[0] if alphas else 0.0, period, True)
        waves = plan_waves(list(queue), preds_of, batch_cap)
        scheduled = [False] * self.n
        for wave_js in waves:
            for j in wave_js:
                for i in preds_of[j]:
                    if not scheduled[i]:
                        raise SchedulingFailure(
                            f"task {j} dequeued before predecessor {i} "
                            f"(Sec. 3.2)")
            for j in wave_js:
                scheduled[j] = True
        faulted = self.faults is not None
        swept = be.evaluate_plan_sweep(waves, list(alphas), period,
                                       timeout=self.wave_timeout)
        out: List[Tuple[Schedule, float, DecisionTrace]] = []
        for alpha, per_wave in zip(alphas, swept):
            messages: Dict[Tuple[int, int], MessagePlacement] = {}
            records: List[DecisionRecord] = []
            bound = _INF
            procs = np.full(self.n, -1, dtype=np.int64)
            ast_ = np.zeros(self.n)
            aft_ = np.zeros(self.n)
            bid = 0
            for wave_js, decisions in zip(waves, per_wave):
                for j, (p, est, eft, msgs, ca, cb, contrib) in zip(
                        wave_js, decisions):
                    if faulted and not eft < INFEASIBLE_EFT:
                        raise InfeasibleScheduleError(j, eft, self.faults)
                    for (i, route, iv) in msgs:
                        messages[(i, j)] = MessagePlacement(
                            (i, j), int(procs[i]), p, route,
                            [(names[lid], s_, f) for (lid, s_, f) in iv])
                    procs[j] = p
                    ast_[j] = est
                    aft_[j] = eft
                    if contrib < bound:
                        bound = contrib
                    records.append((j, p, est, eft, msgs, ca, cb, bid))
                bid += 1
            self.n_decisions_simulated += len(records)
            tr = DecisionTrace(tuple(queue), alpha, period, True, records)
            out.append((Schedule(g, tg, procs, ast_, aft_, messages,
                                 alpha=alpha), bound, tr))
        return out

    # ------------------------------------------------------------------
    def _run(self, queue: Sequence[int], alpha: float,
             period: Optional[float], want_bound: bool,
             record: bool = False,
             resume: Optional[DecisionTrace] = None,
             resume_pos: int = 0,
             backend: Optional[str] = None,
             batch: Optional[int] = None
             ) -> Tuple[Schedule, float, Optional[DecisionTrace]]:
        g, tg = self.g, self.tg
        preds_of = self._preds
        names = self._link_names
        if period is None:
            period = self.default_period
        batch_cap = validate_batch(batch)
        if batch_cap is None:
            batch_cap = DEFAULT_BATCH_MAX

        be = self.backend_instance(backend)
        be.start(alpha, period, want_bound)
        proc_of = be.proc_of
        scheduled = [False] * self.n
        messages: Dict[Tuple[int, int], MessagePlacement] = {}
        bound = _INF
        records: List[DecisionRecord] = []

        start = 0
        bid = 0                      # next live batch id (monotone in-trace)
        if resume is not None and resume_pos > 0:
            if resume.alpha != alpha or resume.want_bound != want_bound \
                    or resume.period != period:
                raise ValueError("resume trace was recorded under different "
                                 "(alpha, period, bound-tracking) settings")
            if tuple(queue[:resume_pos]) != resume.queue[:resume_pos]:
                raise ValueError("resume trace queue prefix mismatch")
            start = resume_pos
            # Re-commit the memoized prefix: the same floating-point state
            # updates in the same order as the original run — no candidate
            # evaluation, no route walks.  Record commits are shared scalar
            # code, so the trace may come from any backend (and any batch
            # grouping: decisions are batch-invariant, the recorded batch
            # id is carried along untouched).
            for rec in resume.records[:resume_pos]:
                j, p, est, eft, msgs, ca, cb, rec_bid = rec
                be.apply(j, p, est, eft, msgs)
                for (i, route, iv) in msgs:
                    messages[(i, j)] = MessagePlacement(
                        (i, j), proc_of[i], p, route,
                        [(names[lid], s_, f) for (lid, s_, f) in iv])
                scheduled[j] = True
                if want_bound and ca is not None:
                    # same crossing-point arithmetic as the live path, on
                    # the memoized candidate coefficients
                    b = be.crossing(p, ca, cb, alpha)
                    if b < bound:
                        bound = b
                if record:
                    records.append(rec)
                bid = rec_bid + 1    # a resumed suffix may split a batch
            self.n_decisions_replayed += resume_pos

        # Level-batched queue walk, planned **up front**: the wave plan
        # is a pure function of (queue, precedence edges, cap) — see
        # :func:`plan_waves` — so the engine emits the complete plan,
        # proves precedence safety over it, and hands the whole thing to
        # the backend in ONE ``evaluate_plan`` call.  The sequential
        # default walks it wave-by-wave through ``evaluate_batch`` (the
        # exact op order of the old interleaved loop — scalar/vector stay
        # bit-exact); the Pallas backend folds the entire plan into a
        # single device dispatch (DESIGN.md §5).  Decisions inside a
        # wave still interact through link/processor state and are
        # evaluated sequentially; the contract is batch-invariance.
        q = list(queue[start:]) if start else list(queue)
        waves = plan_waves(q, preds_of, batch_cap)
        for wave_js in waves:
            for j in wave_js:
                for i in preds_of[j]:
                    if not scheduled[i]:
                        raise SchedulingFailure(
                            f"task {j} dequeued before predecessor {i} "
                            f"(Sec. 3.2)")
            for j in wave_js:
                scheduled[j] = True
        sim_count = 0
        faulted = self.faults is not None
        per_wave = be.evaluate_plan(waves, timeout=self.wave_timeout,
                                    bid0=bid)
        for wave_js, decisions in zip(waves, per_wave):
            for j, (p, est, eft, msgs, ca, cb, contrib) in zip(wave_js,
                                                               decisions):
                if faulted and not eft < INFEASIBLE_EFT:
                    # the *winner* is only reachable through a masked
                    # resource: no feasible placement exists for j
                    raise InfeasibleScheduleError(j, eft, self.faults)
                for (i, route, iv) in msgs:
                    messages[(i, j)] = MessagePlacement(
                        (i, j), proc_of[i], p, route,
                        [(names[lid], s_, f) for (lid, s_, f) in iv])
                if contrib < bound:
                    bound = contrib
                if record:
                    records.append((j, p, est, eft, msgs, ca, cb, bid))
            sim_count += len(wave_js)
            bid += 1

        self.n_decisions_simulated += sim_count
        trace = DecisionTrace(tuple(queue), alpha,
                              period, want_bound, records) if record else None
        return Schedule(g, tg, np.array(proc_of), np.array(be.ast),
                        np.array(be.aft), messages, alpha=alpha), bound, trace
