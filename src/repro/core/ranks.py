"""Task-prioritizing phase: per-processor ranks and HPRV values (Section 4.1).

Unlike HEFT-style averaging, the rank of Eq. 2 is computed *per source
processor* using that processor's data-transfer speed (Eq. 5/6), which is
what makes the priorities accurate on heterogeneous networks.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .graph import SPG
from .topology import Topology


def rank_matrix_reference(g: SPG, tg: Topology) -> np.ndarray:
    """Scalar-loop reference for :func:`rank_matrix` (kept for the
    engine-equivalence tests; bit-identical to the vectorized path)."""
    P = tg.n_procs
    rank = np.zeros((g.n, P))
    speeds = np.array([tg.proc_speed(p) for p in range(P)])
    for u in reversed(g.topo_order):
        for p in range(P):
            c = g.comp(u, p, tg.rates)
            if not g.succ[u]:
                rank[u, p] = c
                continue
            best = 0.0
            for v in g.succ[u]:
                tpl = g.comm_volume(u, v, c)
                comm = tpl / speeds[p]           # Eq. 6
                best = max(best, rank[v, p] + comm)
            rank[u, p] = c + best
    return rank


def rank_matrix(g: SPG, tg: Topology) -> np.ndarray:
    """``rank(n_i, p_src)`` for every task/processor pair (Eq. 2).

    Returns an (n_tasks, n_procs) array.  Computed as a level sweep: nodes
    are grouped by height (longest path to an exit) and each level's ranks
    come from one batched gather + masked max over the padded successor
    table.  Every elementwise op (tpl scaling, the Eq. 6 division, the
    final max/add) matches the scalar reference op-for-op, so the result
    is bit-identical to :func:`rank_matrix_reference`.
    """
    P = tg.n_procs
    n = g.n
    comp = g.comp_matrix_for(tg.rates)
    speeds = np.array([tg.proc_speed(p) for p in range(P)])
    rank = np.zeros((n, P))

    # height = longest path to an exit; nodes at the same height have all
    # successors strictly below, so a level can be computed in one batch.
    height = np.zeros(n, dtype=int)
    for u in reversed(g.topo_order):
        for v in g.succ[u]:
            if height[v] + 1 > height[u]:
                height[u] = height[v] + 1
    levels: List[List[int]] = [[] for _ in range(int(height.max()) + 1)]
    for u in range(n):
        levels[height[u]].append(u)

    exits = np.array(levels[0], dtype=int)
    rank[exits] = comp[exits]
    ccr = g.tpl_proportional_ccr
    for lvl in levels[1:]:
        nodes = np.array(lvl, dtype=int)
        m = max(len(g.succ[u]) for u in lvl)
        succ_pad = np.zeros((len(lvl), m), dtype=int)
        mask = np.zeros((len(lvl), m), dtype=bool)
        for r_, u in enumerate(lvl):
            su = g.succ[u]
            succ_pad[r_, :len(su)] = su
            mask[r_, :len(su)] = True
        gathered = rank[succ_pad]                        # (k, m, P)
        if ccr is not None:
            # tpl(e_uv | p) = CCR * comp(u, p): same for every successor
            comm = (ccr * comp[nodes]) / speeds          # (k, P), Eq. 6
            contrib = gathered + comm[:, None, :]
        else:
            tpl_pad = np.zeros((len(lvl), m))
            for r_, u in enumerate(lvl):
                for c_, v in enumerate(g.succ[u]):
                    tpl_pad[r_, c_] = g.tpl[(u, v)]
            comm = tpl_pad[:, :, None] / speeds[None, None, :]
            contrib = gathered + comm
        contrib = np.where(mask[:, :, None], contrib, -np.inf)
        best = np.maximum(contrib.max(axis=1), 0.0)      # reference init 0.0
        rank[nodes] = comp[nodes] + best
    return rank


def hrank(g: SPG, tg: Topology, rank: np.ndarray | None = None) -> np.ndarray:
    """Average rank over all processors (Eq. 7)."""
    rank = rank_matrix(g, tg) if rank is None else rank
    return rank.mean(axis=1)


def hprv_a(g: SPG, tg: Topology, rank: np.ndarray | None = None) -> np.ndarray:
    """HPRV_CC (A): ``hrank * outd`` (Eq. 8) — the HSV_CC prioritizer."""
    h = hrank(g, tg, rank)
    outd = np.array([g.outd(i) for i in range(g.n)], dtype=float)
    return h * outd


def hprv_b(g: SPG, tg: Topology, rank: np.ndarray | None = None,
           depth_power: int = 2, outd_mode: str = "indicator") -> np.ndarray:
    """HPRV_CC (B): the depth-damped prioritizer (Eq. 9).

    ``outd_mode="indicator"`` (default) treats the out-degree factor as a
    presence indicator (exit tasks 0, everything else 1), i.e.
    ``HPRV = hrank / depth**k``.  This is what the paper's own Table 2
    evaluates (n6: 38.6/4 = 9.7, n7: 50.2/9 = 5.6 — the printed values
    carry *no* outd/max_outd factor for outd=1 nodes), and it makes the
    paper's Experiment-4 headline (SFR = 0%) a theorem:

      For every edge (p, s): rank(p, u) >= comp(p, u) + rank(s, u) +
      comm > rank(s, u) on every processor u, hence hrank(p) > hrank(s);
      and depth(p) < depth(s).  Therefore HPRV(p) > HPRV(s) strictly for
      any depth_power >= 1 — a successor can never be dequeued before its
      predecessor.

    ``outd_mode="literal"`` is Eq. 9 exactly as printed
    (``hrank * outd/max_outd / depth**k``); it reproduces the paper's
    depth^1 ablation (~29% SFR) but retains a small failure rate even at
    k=2 (see DESIGN.md §9 for the contradiction in the paper).
    ``depth_power=1`` reproduces the HVLB_CC(depth) ablation.
    """
    h = hrank(g, tg, rank)
    outd = np.array([g.outd(i) for i in range(g.n)], dtype=float)
    if outd_mode == "indicator":
        factor = (outd > 0).astype(float)
    elif outd_mode == "literal":
        factor = outd / (float(g.max_outd) or 1.0)
    else:
        raise ValueError(f"unknown outd_mode {outd_mode!r}")
    return h * factor / (g.depth.astype(float) ** depth_power)


def ldet_cc(g: SPG, tg: Topology, rank: np.ndarray | None = None) -> np.ndarray:
    """Longest-distance exit time (Eq. 16): ``rank - comp``; 1.0 for exits."""
    rank = rank_matrix(g, tg) if rank is None else rank
    out = rank - g.comp_matrix_for(tg.rates)
    exits = [i for i in range(g.n) if not g.succ[i]]
    out[exits] = 1.0
    return out


def priority_queue(values: np.ndarray, h: np.ndarray) -> List[int]:
    """Non-increasing HPRV order; ties broken by hrank, then node index.

    Reproduces the paper's queues for Fig. 3 (A: n1,n2,n3,n4,n5,n7,n6,n8,
    n9,n10 — note the n3/n4 HPRV tie resolved by index; B: n1..n10).
    """
    return sorted(range(len(values)),
                  key=lambda i: (-round(values[i], 6), -round(h[i], 6), i))
