"""Core paper algorithms: contention-aware, load-balanced static list
scheduling for stream-processing DAGs on heterogeneous processors/networks.
"""
from .engine import CompiledInstance
from .graph import PAPER_COMP, PAPER_COMP_EXP5, PAPER_EDGES, SPG, paper_spg
from .hsv_cc import schedule_hsv_cc
from .hvlb_cc import SweepResult, schedule_hvlb_cc, schedule_hvlb_cc_best
from .imprecise import precision, precision_curve, schedule_holes
from .metrics import load_balance, sfr, slr, speedup
from .ranks import hprv_a, hprv_b, hrank, ldet_cc, priority_queue, rank_matrix
from .scheduler import (MessagePlacement, Schedule, SchedulingFailure,
                        list_schedule)
from .tgff import random_spg
from .topology import Topology, fully_switched_topology, paper_topology

__all__ = [
    "CompiledInstance",
    "SPG", "paper_spg", "PAPER_EDGES", "PAPER_COMP", "PAPER_COMP_EXP5",
    "Topology", "paper_topology", "fully_switched_topology",
    "rank_matrix", "hrank", "hprv_a", "hprv_b", "ldet_cc", "priority_queue",
    "Schedule", "MessagePlacement", "SchedulingFailure", "list_schedule",
    "schedule_hsv_cc", "schedule_hvlb_cc", "schedule_hvlb_cc_best",
    "SweepResult", "schedule_holes", "precision", "precision_curve",
    "slr", "speedup", "load_balance", "sfr", "random_spg",
]
