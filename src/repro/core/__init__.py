"""Core paper algorithms: contention-aware, load-balanced static list
scheduling for stream-processing DAGs on heterogeneous processors/networks.
"""
from .api import (HSV_CC, HVLB_CC_A, HVLB_CC_B, HVLB_CC_IC, FleetPlan,
                  Plan, Policy, ReplayStats, Scheduler, SweepResult)
from .backends import (CandidateEvaluator, ScalarBackend, VectorBackend,
                       available_backends, default_backend,
                       resolve_backend_name)
from .engine import CompiledInstance, DecisionTrace
from .faults import (ComputeSpike, Fault, FaultSpec, InfeasibleScheduleError,
                     LinkDegraded, LinkDown, ProcessorDown, WaveTimeoutError,
                     apply_to_graph, apply_to_topology)
from .graph import PAPER_COMP, PAPER_COMP_EXP5, PAPER_EDGES, SPG, paper_spg
from .hsv_cc import schedule_hsv_cc
from .hvlb_cc import schedule_hvlb_cc, schedule_hvlb_cc_best
from .imprecise import precision, precision_curve, schedule_holes
from .metrics import load_balance, sfr, slr, speedup
from .ranks import hprv_a, hprv_b, hrank, ldet_cc, priority_queue, rank_matrix
from .scheduler import (MessagePlacement, Schedule, SchedulingFailure,
                        list_schedule)
from .tgff import random_spg
from .topology import Topology, fully_switched_topology, paper_topology
from .validate import (ScheduleValidationError, schedule_violations,
                       validate_schedule)

__all__ = [
    # session API (the supported public surface)
    "Scheduler", "Plan", "FleetPlan", "Policy", "ReplayStats",
    "HSV_CC", "HVLB_CC_A", "HVLB_CC_B", "HVLB_CC_IC", "SweepResult",
    "CompiledInstance", "DecisionTrace",
    # candidate-evaluation backends
    "CandidateEvaluator", "ScalarBackend", "VectorBackend",
    "available_backends", "default_backend", "resolve_backend_name",
    # fault model + independent validation (DESIGN.md §6)
    "Fault", "FaultSpec", "ProcessorDown", "LinkDegraded", "LinkDown",
    "ComputeSpike", "InfeasibleScheduleError", "WaveTimeoutError",
    "apply_to_topology", "apply_to_graph",
    "schedule_violations", "validate_schedule", "ScheduleValidationError",
    "SPG", "paper_spg", "PAPER_EDGES", "PAPER_COMP", "PAPER_COMP_EXP5",
    "Topology", "paper_topology", "fully_switched_topology",
    "rank_matrix", "hrank", "hprv_a", "hprv_b", "ldet_cc", "priority_queue",
    "Schedule", "MessagePlacement", "SchedulingFailure", "list_schedule",
    "schedule_holes", "precision", "precision_curve",
    "slr", "speedup", "load_balance", "sfr", "random_spg",
    # deprecated one-shot shims
    "schedule_hsv_cc", "schedule_hvlb_cc", "schedule_hvlb_cc_best",
]
