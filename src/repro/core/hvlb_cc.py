"""HVLB_CC (A) and (B): load-balanced, contention-aware list scheduling
(Algorithm 1 of the paper).

Variant A keeps HSV_CC's prioritizer (Eq. 8); variant B uses the
depth^2-damped prioritizer (Eq. 9) that makes arbitrary stream-processing
graphs schedulable.  Both sweep the balancing weight ``alpha`` and keep the
minimum-makespan schedule.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .graph import SPG
from .ranks import hprv_a, hprv_b, priority_queue, rank_matrix
from .scheduler import Schedule, SchedulingFailure, list_schedule
from .topology import Topology


@dataclasses.dataclass
class SweepResult:
    best: Schedule
    best_alpha: float
    curve: List[Tuple[float, float]]     # (alpha, makespan) — Fig. 5 data


def schedule_hvlb_cc(g: SPG, tg: Topology, variant: str = "A",
                     alpha_max: float = 3.0, alpha_step: float = 0.01,
                     period: Optional[float] = None,
                     depth_power: int = 2,
                     outd_mode: str = "indicator") -> SweepResult:
    """Algorithm 1: sweep alpha in [0, alpha_max], keep min makespan."""
    rank = rank_matrix(g, tg)
    h = rank.mean(axis=1)
    if variant.upper() == "A":
        prv = hprv_a(g, tg, rank)
    elif variant.upper() == "B":
        prv = hprv_b(g, tg, rank, depth_power=depth_power,
                     outd_mode=outd_mode)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    queue = priority_queue(prv, h)

    best: Optional[Schedule] = None
    best_alpha = 0.0
    curve: List[Tuple[float, float]] = []
    n_steps = int(round(alpha_max / alpha_step))
    for k in range(n_steps + 1):
        alpha = k * alpha_step
        s = list_schedule(g, tg, queue, rank, alpha=alpha, period=period)
        curve.append((alpha, s.makespan))
        if best is None or s.makespan < best.makespan - 1e-12:
            best, best_alpha = s, alpha
    assert best is not None
    return SweepResult(best, best_alpha, curve)


def schedule_hvlb_cc_best(g: SPG, tg: Topology, **kw) -> Schedule:
    return schedule_hvlb_cc(g, tg, **kw).best
