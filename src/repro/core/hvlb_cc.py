"""HVLB_CC (A) and (B): load-balanced, contention-aware list scheduling
(Algorithm 1 of the paper).

Variant A keeps HSV_CC's prioritizer (Eq. 8); variant B uses the
depth^2-damped prioritizer (Eq. 9) that makes arbitrary stream-processing
graphs schedulable.  Both sweep the balancing weight ``alpha`` and keep the
minimum-makespan schedule.

The sweep runs on the compiled engine by default: one
:class:`~repro.core.engine.CompiledInstance` is shared across every alpha
step, and each simulated step reports the alpha interval over which its
decision trace stays optimal, so grid points inside the interval reuse the
schedule without re-simulation (see ``engine.py``).  ``engine="reference"``
re-runs the readable ``list_schedule`` at every step instead — the two
paths produce bit-identical results.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .engine import CompiledInstance
from .graph import SPG
from .ranks import hprv_a, hprv_b, ldet_cc, priority_queue, rank_matrix
from .scheduler import Schedule, list_schedule
from .topology import Topology

# Grid alphas closer than this to a predicted trace-flip point are
# re-simulated rather than skipped (guards the last-ulp difference between
# the linear prediction A + B*alpha and the simulated Def. 4.1 value).
_SKIP_MARGIN = 1e-6


@dataclasses.dataclass
class SweepResult:
    best: Schedule
    best_alpha: float
    curve: List[Tuple[float, float]]     # (alpha, makespan) — Fig. 5 data


def _queue_for(g: SPG, tg: Topology, variant: str, rank: np.ndarray,
               depth_power: int, outd_mode: str) -> List[int]:
    h = rank.mean(axis=1)
    if variant.upper() == "A":
        prv = hprv_a(g, tg, rank)
    elif variant.upper() == "B":
        prv = hprv_b(g, tg, rank, depth_power=depth_power,
                     outd_mode=outd_mode)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return priority_queue(prv, h)


def _sweep_grid(inst: CompiledInstance, queue: Sequence[int],
                alphas: Sequence[float], period: Optional[float],
                curve: List[Tuple[float, float]],
                best: Optional[Schedule], best_alpha: float
                ) -> Tuple[Optional[Schedule], float]:
    """Engine sweep over a sorted alpha grid with trace-interval skipping."""
    k = 0
    while k < len(alphas):
        alpha = alphas[k]
        s, bnd = inst.schedule_with_bound(queue, alpha, period=period)
        curve.append((alpha, s.makespan))
        if best is None or s.makespan < best.makespan - 1e-12:
            best, best_alpha = s, alpha
        k += 1
        # identical decision trace => identical schedule: skip re-simulation
        while k < len(alphas) and alphas[k] < bnd - _SKIP_MARGIN:
            curve.append((alphas[k], s.makespan))
            k += 1
    return best, best_alpha


def schedule_hvlb_cc(g: SPG, tg: Topology, variant: str = "A",
                     alpha_max: float = 3.0, alpha_step: float = 0.01,
                     period: Optional[float] = None,
                     depth_power: int = 2,
                     outd_mode: str = "indicator",
                     engine: str = "compiled",
                     sweep: str = "grid",
                     coarse_factor: int = 10) -> SweepResult:
    """Algorithm 1: sweep alpha in [0, alpha_max], keep min makespan.

    ``engine="compiled"`` (default) shares one ``CompiledInstance`` across
    the sweep and skips re-simulating alphas whose decision trace is
    provably unchanged; ``engine="reference"`` runs ``list_schedule`` per
    step.  ``sweep="adaptive"`` (opt-in, compiled only) evaluates a coarse
    grid of ``coarse_factor * alpha_step`` first and refines at
    ``alpha_step`` only around the best coarse plateau — the curve then
    contains just the evaluated points.
    """
    if sweep not in ("grid", "adaptive"):
        raise ValueError(f"unknown sweep {sweep!r}")
    if engine == "reference" and sweep != "grid":
        raise ValueError("sweep='adaptive' requires engine='compiled'")
    rank = rank_matrix(g, tg)
    queue = _queue_for(g, tg, variant, rank, depth_power, outd_mode)
    n_steps = int(round(alpha_max / alpha_step))

    if engine == "reference":
        ldet = ldet_cc(g, tg, rank)
        best: Optional[Schedule] = None
        best_alpha = 0.0
        curve: List[Tuple[float, float]] = []
        for k in range(n_steps + 1):
            alpha = k * alpha_step
            s = list_schedule(g, tg, queue, rank, alpha=alpha, period=period,
                              ldet=ldet)
            curve.append((alpha, s.makespan))
            if best is None or s.makespan < best.makespan - 1e-12:
                best, best_alpha = s, alpha
        assert best is not None
        return SweepResult(best, best_alpha, curve)
    if engine != "compiled":
        raise ValueError(f"unknown engine {engine!r}")

    inst = CompiledInstance(g, tg, rank=rank)
    curve = []
    if sweep == "grid":
        alphas = [k * alpha_step for k in range(n_steps + 1)]
        best, best_alpha = _sweep_grid(inst, queue, alphas, period,
                                       curve, None, 0.0)
    elif sweep == "adaptive":
        coarse = [k * alpha_step for k in range(0, n_steps + 1,
                                                max(1, coarse_factor))]
        if coarse[-1] != n_steps * alpha_step:
            coarse.append(n_steps * alpha_step)
        best, best_alpha = _sweep_grid(inst, queue, coarse, period,
                                       curve, None, 0.0)
        assert best is not None
        # refine at alpha_step around every coarse point within 2% of the
        # coarse optimum (a single window can miss a narrow global plateau)
        cutoff = best.makespan * 1.02
        refine_steps: set = set()
        for a, m in curve:
            if m <= cutoff:
                ka = int(round(a / alpha_step))
                refine_steps.update(range(max(0, ka - coarse_factor),
                                          min(n_steps, ka + coarse_factor) + 1))
        done = {round(a, 12) for a, _ in curve}
        fine = [k * alpha_step for k in sorted(refine_steps)
                if round(k * alpha_step, 12) not in done]
        best, best_alpha = _sweep_grid(inst, queue, fine, period,
                                       curve, best, best_alpha)
        curve.sort()
    assert best is not None
    return SweepResult(best, best_alpha, curve)


def schedule_hvlb_cc_best(g: SPG, tg: Topology, **kw) -> Schedule:
    return schedule_hvlb_cc(g, tg, **kw).best
