"""HVLB_CC (A) and (B) one-shot entry points — deprecated shims.

These wrap :class:`repro.core.api.Scheduler` (a throwaway single-graph
session) and produce bit-identical results to the pre-session API; new
code should hold a ``Scheduler`` instead, which shares the compiled
instance, priority queues, and decision traces across calls and exposes
``submit_many`` / incremental ``update``.  The shims are kept so the
paper-experiment drivers and downstream users keep working; they emit a
:class:`DeprecationWarning` once per process (see
:mod:`repro.core.deprecation`) and will be removed once nothing in-tree
imports them (DESIGN.md §4, "Deprecation policy").
"""
from __future__ import annotations

from typing import Optional

from .api import HVLB_CC_A, HVLB_CC_B, Scheduler, SweepResult
from .deprecation import warn_once
from .graph import SPG
from .scheduler import Schedule
from .topology import Topology

__all__ = ["SweepResult", "schedule_hvlb_cc", "schedule_hvlb_cc_best"]


def _run(g: SPG, tg: Topology, variant: str = "A", alpha_max: float = 3.0,
         alpha_step: float = 0.01, period: Optional[float] = None,
         depth_power: int = 2, outd_mode: str = "indicator",
         engine: str = "compiled", sweep: str = "grid",
         coarse_factor: int = 10,
         backend: Optional[str] = None) -> SweepResult:
    """Shared implementation (and single source of defaults) of the two
    deprecated shims below."""
    if variant.upper() == "A":
        policy = HVLB_CC_A(alpha_max=alpha_max, alpha_step=alpha_step,
                           period=period, sweep=sweep,
                           coarse_factor=coarse_factor)
    elif variant.upper() == "B":
        policy = HVLB_CC_B(alpha_max=alpha_max, alpha_step=alpha_step,
                           period=period, sweep=sweep,
                           coarse_factor=coarse_factor,
                           depth_power=depth_power, outd_mode=outd_mode)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return Scheduler(tg, policy=policy, engine=engine,
                     backend=backend).submit(g).sweep


def schedule_hvlb_cc(g: SPG, tg: Topology, variant: str = "A",
                     alpha_max: float = 3.0, alpha_step: float = 0.01,
                     period: Optional[float] = None,
                     depth_power: int = 2,
                     outd_mode: str = "indicator",
                     engine: str = "compiled",
                     sweep: str = "grid",
                     coarse_factor: int = 10,
                     backend: Optional[str] = None) -> SweepResult:
    """Algorithm 1: sweep alpha in [0, alpha_max], keep min makespan.

    .. deprecated:: use ``Scheduler(tg, policy=HVLB_CC_A(...)).submit(g)``;
       the returned ``Plan.sweep`` is this function's ``SweepResult``.
    """
    warn_once("schedule_hvlb_cc",
              "schedule_hvlb_cc is deprecated; use repro.core.Scheduler "
              "with an HVLB_CC_A/HVLB_CC_B policy")
    return _run(g, tg, variant, alpha_max, alpha_step, period, depth_power,
                outd_mode, engine, sweep, coarse_factor, backend)


def schedule_hvlb_cc_best(g: SPG, tg: Topology, **kw) -> Schedule:
    """Deprecated: ``Scheduler(...).submit(g).schedule``."""
    warn_once("schedule_hvlb_cc_best",
              "schedule_hvlb_cc_best is deprecated; use "
              "repro.core.Scheduler")
    return _run(g, tg, **kw).best
