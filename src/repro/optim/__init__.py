from .adamw import (AdamWConfig, OptState, abstract_opt_state, adamw_update,
                    global_norm, init_opt_state, opt_state_specs)
from .compress import compress_grads, decompress_grads
