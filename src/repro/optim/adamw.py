"""AdamW with global-norm clipping and linear-warmup cosine schedule.

Hand-rolled (no optax dependency): state is ``{mu, nu, step}`` with mu/nu
sharded exactly like the parameters (the dominant optimizer memory).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, _is_spec, param_specs

Tree = Any


class OptState(NamedTuple):
    mu: Tree
    nu: Tree
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def init_opt_state(params: Tree) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(z, jax.tree.map(jnp.copy, z),
                    jnp.zeros((), jnp.int32))


def abstract_opt_state(cfg: ModelConfig) -> OptState:
    specs = param_specs(cfg)
    ab = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), specs,
        is_leaf=_is_spec)
    return OptState(ab, jax.tree.map(lambda x: x, ab),
                    jax.ShapeDtypeStruct((), jnp.int32))


def opt_state_specs(cfg: ModelConfig) -> OptState:
    """ParamSpec tree (for shardings) mirroring the param layout."""
    specs = param_specs(cfg)
    f32 = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.axes, s.init, jnp.float32), specs,
        is_leaf=_is_spec)
    return OptState(f32, jax.tree.map(lambda x: x, f32, is_leaf=_is_spec),
                    ParamSpec((), ()))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree: Tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def adamw_update(opt_cfg: AdamWConfig, params: Tree, grads: Tree,
                 state: OptState) -> tuple[Tree, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt_cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = _schedule(opt_cfg, state.step)
    b1, b2 = opt_cfg.b1, opt_cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + opt_cfg.eps)
        u = u + opt_cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu, nu, step), {"grad_norm": gnorm, "lr": lr}
