"""Gradient compression (beyond-paper distributed-optimization trick).

int8 per-tensor-scaled quantization with error feedback: the compressor
runs *before* the cross-replica reduction so the all-reduce moves 4x fewer
bytes for fp32 grads; the residual is carried to the next step.  Off by
default; §Perf measures the collective-term effect.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def compress_grads(grads: Tree, residual: Optional[Tree] = None
                   ) -> Tuple[Tree, Tree, Tree]:
    """Returns (q_int8, scales, new_residual)."""
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g + r, grads, residual)

    def q(g):
        a = jnp.max(jnp.abs(g)) + 1e-12
        scale = a / 127.0
        qi = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return qi, scale

    flat, tdef = jax.tree.flatten(grads)
    qs = [q(g) for g in flat]
    qi = jax.tree.unflatten(tdef, [x[0] for x in qs])
    sc = jax.tree.unflatten(tdef, [x[1] for x in qs])
    deq = jax.tree.map(lambda i, s: i.astype(jnp.float32) * s, qi, sc)
    new_res = jax.tree.map(lambda g, d: g - d, grads, deq)
    return qi, sc, new_res


def decompress_grads(qi: Tree, scales: Tree) -> Tree:
    return jax.tree.map(lambda i, s: i.astype(jnp.float32) * s, qi, scales)
