"""Model/config schema for the assigned architectures and their shapes.

Every architecture is a :class:`ModelConfig`; every workload cell is a
(arch, :class:`ShapeConfig`) pair.  ``input_specs`` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # attention flavor
    rope: str = "standard"           # standard | partial | mrope | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    causal: bool = True
    # mlp flavor
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM
    ssm: str = ""                    # "" | mamba1 | mamba2
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64           # mamba2 head dim
    # hybrid (zamba2): shared attention block applied every k SSM layers
    attn_every: int = 0
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # modality frontend stub: model consumes precomputed frame/patch embeds
    embed_inputs: bool = False       # audio: inputs are (B, S, D) embeddings
    vision_prefix: bool = False      # vlm: first S//4 positions come from
    #                                  precomputed patch embeddings
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank(self) -> int:
        return max(1, int(np.ceil(self.d_model / 16)))

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    @property
    def decoder(self) -> bool:
        """Has a decode step (hubert is encoder-only)."""
        return self.family != "audio"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, K, dh = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * D                              # embedding
        if not self.tie_embeddings:
            total += V * D                         # lm head
        attn = D * (H * dh) + 2 * D * (K * dh) + (H * dh) * D
        if self.qkv_bias:
            attn += (H + 2 * K) * dh
        mlp_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        mlp = mlp_mult * D * F
        if self.family in ("dense", "vlm", "audio"):
            total += self.n_layers * (attn + mlp + 2 * D)
        elif self.family == "moe":
            total += self.n_layers * (attn + self.n_experts * mlp + D * self.n_experts + 2 * D)
        elif self.family == "ssm":
            total += self.n_layers * (self._mamba1_params() + D)
        elif self.family == "hybrid":
            total += self.n_layers * (self._mamba2_params() + D)
            total += attn + mlp + 2 * D            # one shared block
        return total

    def _mamba1_params(self) -> int:
        D, Di, N, R = self.d_model, self.d_inner, self.d_state, self.dt_rank
        return (D * 2 * Di + self.d_conv * Di + Di * (R + 2 * N) +
                R * Di + Di * N + Di + Di * D)

    def _mamba2_params(self) -> int:
        D, Di, N = self.d_model, self.d_inner, self.d_state
        Hs = self.n_ssm_heads
        return (D * (2 * Di + 2 * N + Hs) + self.d_conv * (Di + 2 * N) +
                Hs + Hs + Di + Di * D)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        mlp = 3 * D * F if self.mlp in ("swiglu", "geglu") else 2 * D * F
        dense = self.param_count() - self.n_layers * self.n_experts * mlp
        return dense + self.n_layers * self.top_k * mlp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a defined cell; reason if not."""
    if shape.kind == "decode" and not cfg.decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For decode cells the specs describe ONE serve_step invocation: a single
    new token per sequence plus the persistent cache state (which is passed
    separately — see launch.dryrun).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind in ("train", "prefill"):
        if cfg.embed_inputs:                      # audio stub frontend
            specs = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f)}
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.vision_prefix:                 # vlm stub frontend
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, S // 4, cfg.d_model), f)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    # decode: one new token, plus current positions
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "positions": jax.ShapeDtypeStruct((B,), i32),
    }
