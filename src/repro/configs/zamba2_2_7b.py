"""zamba2-2.7b [hybrid]: 54L Mamba-2 d=2560 + shared attention block
(32H kv=32, d_ff=10240) every 6 layers, vocab 32000, ssm_state=64
[arXiv:2411.15242]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, d_head=80, ssm="mamba2", d_state=64, d_conv=4, expand=2,
    ssm_head_dim=64, attn_every=6, rope="standard", mlp="swiglu",
)
