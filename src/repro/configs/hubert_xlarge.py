"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) d_ff=5120 vocab=504,
encoder-only; conv waveform frontend STUBBED (precomputed frame
embeddings)  [arXiv:2106.07447]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, rope="none", causal=False, mlp="gelu", embed_inputs=True,
)
