"""Architecture registry: --arch <id> resolves here."""
from .base import (ModelConfig, ShapeConfig, SHAPES, cell_supported,
                   input_specs)

from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .chatglm3_6b import CONFIG as chatglm3_6b
from .qwen3_8b import CONFIG as qwen3_8b
from .qwen2_0_5b import CONFIG as qwen2_0_5b
from .phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from .zamba2_2_7b import CONFIG as zamba2_2_7b
from .dbrx_132b import CONFIG as dbrx_132b
from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .qwen2_vl_7b import CONFIG as qwen2_vl_7b
from .hubert_xlarge import CONFIG as hubert_xlarge

ARCHS = {
    c.name: c for c in [
        falcon_mamba_7b, chatglm3_6b, qwen3_8b, qwen2_0_5b,
        phi3_mini_3_8b, zamba2_2_7b, dbrx_132b, olmoe_1b_7b,
        qwen2_vl_7b, hubert_xlarge,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses
    kw = dict(
        n_layers=2, d_model=64, vocab=256,
        d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        d_head=16 if cfg.n_heads else 0,
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4
    if cfg.family == "moe":
        kw["n_experts"] = 4
        kw["top_k"] = 2
    if cfg.ssm:
        kw["d_state"] = min(cfg.d_state, 8)
        kw["ssm_head_dim"] = 16
    if cfg.family == "hybrid":
        kw["attn_every"] = 1
        kw["n_layers"] = 2
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
