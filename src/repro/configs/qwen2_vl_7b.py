"""qwen2-vl-7b [vlm]: 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
M-RoPE, dynamic-resolution vision frontend STUBBED (precomputed patch
embeddings fill the sequence prefix)  [arXiv:2409.12191]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, rope="mrope", mlp="swiglu", vision_prefix=True,
)
