"""Production serving launcher: batched decode with the DSMS query engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --max-seq 64 --steps 8 --reduced
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--sched-backend", type=str, default=None,
                    choices=["auto", "scalar", "vector", "pallas"],
                    help="candidate-evaluation backend for the DSMS "
                         "static scheduler (DESIGN.md §5)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch, reduced_config
    from repro.models.params import init_params
    from repro.serve import DSMSEngine, Query

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if not cfg.decoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no serve step")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = DSMSEngine(cfg, params, batch_size=args.batch,
                     max_seq=args.max_seq, backend=args.sched_backend)
    eng.register(Query("argmax_conf",
                       mandatory=lambda lg: jnp.max(
                           jax.nn.softmax(lg[:, -1]), -1)))
    eng.register(Query("topk",
                       mandatory=lambda lg: jax.lax.top_k(lg[:, -1], 5),
                       optional=lambda r: (r[0], r[1],
                                           jnp.sort(r[0])[..., ::-1]),
                       optional_ratio=0.5))
    print(f"{cfg.name}: {len(eng.queries)} registered queries, plan "
          f"makespan {eng.plan.makespan*1e3:.3f} ms")
    toks = np.zeros(args.batch, np.int64)
    t0 = time.time()
    for s in range(args.steps):
        res = eng.step(toks)
        toks = res.tokens
    dt = (time.time() - t0) / args.steps
    print(f"{args.steps} steps, {dt*1e3:.1f} ms/step (batch {args.batch}); "
          f"last tokens {toks.tolist()}")


if __name__ == "__main__":
    main()
