import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
# record memory/cost/collective analysis — proves the distribution config
# is coherent without hardware.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
#       --shape train_4k --mesh pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
#
# The first two lines above MUST stay the first statements in this module:
# jax locks the device count at first init.

import argparse
import json
import re
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, cell_supported, get_arch, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.params import abstract_params, param_shardings
from repro.models.sharding import RuleTable, use_sharding
from repro.optim.adamw import abstract_opt_state
from repro.train.step import (batch_shardings, cache_shardings,
                              make_serve_step, make_train_step, opt_shardings)

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "c128": 16, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f8e4m3": 1, "f8e5m2": 1, "s8": 1, "u8": 1,
                "pred": 1, "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    ``-start`` variants are counted once (their ``-done`` twin carries no
    new transfer).  Bytes are per-device (the HLO is the per-device SPMD
    program).
    """
    out = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        for op in COLLECTIVE_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}(")[0].split(f" {op}-start(")[0]
                if "=" in lhs:
                    lhs = lhs.split("=", 1)[1]
                total = 0
                for dt, dims in _SHAPE_RE.findall(lhs):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    total += n * _DTYPE_BYTES[dt]
                out[op] += total
                break
    return out


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ("temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:                      # CPU backend may not support
        return {"error": str(e)}


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error_msg": str(e)}


def build_cell(arch: str, shape_name: str, mesh, *,
               rules: Optional[RuleTable] = None,
               remat: bool = True, microbatch: int = 1):
    """Returns (jitted_fn, abstract_args) for one cell under the mesh."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"unsupported cell: {why}")
    specs = input_specs(cfg, shape)

    def ctx(f):
        # the sharding context must be active while the function is TRACED
        # (inside .lower()), not just while jax.jit is constructed —
        # otherwise every activation constraint silently no-ops.
        def wrapped(*a):
            with use_sharding(mesh, rules):
                return f(*a)
        return wrapped

    with use_sharding(mesh, rules):
        p_sh = param_shardings(cfg)
        b_sh = batch_shardings(cfg, shape, mesh)
        ab_params = abstract_params(cfg)
        if shape.kind in ("train",):
            step = make_train_step(cfg, remat=remat, microbatch=microbatch)
            o_sh = opt_shardings(cfg)
            ab_opt = abstract_opt_state(cfg)
            fn = jax.jit(ctx(step),
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            args = (ab_params, ab_opt, specs)
        elif shape.kind == "prefill":
            def fwd(params, batch):
                return M.forward(cfg, params, batch, remat=False)
            fn = jax.jit(ctx(fwd), in_shardings=(p_sh, b_sh),
                         out_shardings=None)
            args = (ab_params, specs)
        else:                                   # decode
            serve = make_serve_step(cfg)
            c_sh = cache_shardings(cfg, shape.global_batch, shape.seq_len)
            ab_cache = M.abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len)
            fn = jax.jit(ctx(serve),
                         in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                       b_sh["positions"]),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
            args = (ab_params, ab_cache, specs["tokens"], specs["positions"])
    return fn, args


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             rules: Optional[RuleTable] = None, remat: bool = True,
             microbatch: int = 1, keep_hlo: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    fn, args = build_cell(arch, shape_name, mesh, rules=rules, remat=remat,
                          microbatch=microbatch)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    hlo = compiled.as_text()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_analysis(compiled),
        "cost": _cost_analysis(compiled),
        "collectives": collective_bytes(hlo),
        "n_hlo_lines": hlo.count("\n"),
    }
    if keep_hlo:
        rec["hlo"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--mesh", type=str, default="pod",
                    choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for a in sorted(ARCHS):
            for s in SHAPES:
                ok, why = cell_supported(ARCHS[a], SHAPES[s])
                for mk in ("pod", "multipod"):
                    if ok:
                        cells.append((a, s, mk))
                    else:
                        (outdir / f"{a}__{s}__{mk}.json").write_text(
                            json.dumps({"arch": a, "shape": s, "mesh": mk,
                                        "skipped": why}, indent=1))
    else:
        cells = [(args.arch, args.shape, args.mesh)]

    for (a, s, mk) in cells:
        path = outdir / f"{a}__{s}__{mk}.json"
        if path.exists() and args.all:
            d = json.loads(path.read_text())
            if "cost" in d or "skipped" in d:
                print(f"skip (cached): {a} {s} {mk}")
                continue
        print(f"=== {a} x {s} x {mk} ===", flush=True)
        try:
            rec = run_cell(a, s, mk, remat=not args.no_remat,
                           microbatch=args.microbatch)
            print(json.dumps({k: rec[k] for k in
                              ("chips", "lower_s", "compile_s",
                               "collectives")}, indent=1), flush=True)
            print("memory:", rec["memory"], flush=True)
            flops = rec["cost"].get("flops")
            print(f"cost: flops={flops}", flush=True)
        except Exception as e:
            rec = {"arch": a, "shape": s, "mesh": mk,
                   "failed": f"{type(e).__name__}: {e}"}
            print("FAILED:", rec["failed"], flush=True)
        path.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
