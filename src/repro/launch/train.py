"""Production training launcher.

Wires: arch config -> production (or custom) mesh -> sharded params/opt ->
data pipeline -> jit'd train step with checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --mesh 1x1 --steps 20 --batch 4 --seq 128 --reduced

On a real pod slice, drop --reduced and pass --mesh 16x16 (the process
must see the pod's devices; on CPU the dry-run covers the full configs).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM (data x model) or PxDxM for multi-pod")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    import jax
    from repro.checkpoint import latest_step, restore, save
    from repro.configs import get_arch, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticTokenPipeline
    from repro.launch.mesh import make_mesh
    from repro.models.params import init_params, param_shardings
    from repro.models.sharding import use_sharding
    from repro.optim import AdamWConfig
    from repro.optim.adamw import init_opt_state
    from repro.train import make_train_step
    from repro.train.step import batch_shardings, opt_shardings

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    dims = [int(x) for x in args.mesh.split("x")]
    axes = ("pod", "data", "model")[-len(dims):]
    mesh = make_mesh(tuple(dims), axes)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    pipe = SyntheticTokenPipeline(cfg, shape)
    opt_cfg = AdamWConfig(total_steps=args.steps)

    def traced_step(fn):
        def wrapped(*a):
            with use_sharding(mesh):
                return fn(*a)
        return wrapped

    with use_sharding(mesh):
        p_sh = param_shardings(cfg)
        o_sh = opt_shardings(cfg)
        b_sh = batch_shardings(cfg, shape, mesh)
        step = jax.jit(traced_step(make_train_step(
            cfg, opt_cfg, microbatch=args.microbatch)),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start = 0
    if args.resume and args.ckpt:
        last = latest_step(args.ckpt)
        if last is not None:
            st = restore(args.ckpt, last, {"p": params, "o": opt},
                         shardings={"p": p_sh, "o": o_sh})
            params, opt, start = st["p"], st["o"], last
            print(f"resumed @ {last}")

    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh {dict(zip(axes, dims))}")
    with mesh:
        for s in range(start, args.steps):
            t0 = time.time()
            batch = pipe.device_batch(s, b_sh)
            params, opt, info = step(params, opt, batch)
            loss = float(info["loss"])
            if s % 5 == 0 or s == args.steps - 1:
                print(f"step {s:5d} loss={loss:.4f} "
                      f"gnorm={float(info['grad_norm']):.3f} "
                      f"({time.time()-t0:.2f}s)")
            if args.ckpt and (s + 1) % args.ckpt_every == 0:
                save(args.ckpt, s + 1, {"p": params, "o": opt})
    print("done.")


if __name__ == "__main__":
    main()
