"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod
axis crosses DCN; data/model stay on intra-pod ICI.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devs[:n],
                         **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 1) on one CPU)."""
    import jax
    n = int(np.prod(shape))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         devices=jax.devices()[:n],
                         **_axis_type_kwargs(len(axes)))


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on jax >= 0.5; older versions default to
    Auto semantics anyway."""
    import jax.sharding as shd
    if hasattr(shd, "AxisType"):
        return {"axis_types": (shd.AxisType.Auto,) * n_axes}
    return {}
