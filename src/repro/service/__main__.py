"""``python -m repro.service`` — stdlib asyncio TCP front-end.

Newline-delimited JSON requests in, responses out (see
:mod:`repro.service.protocol`); requests on one connection are
*pipelined* — the server dispatches each line as it arrives and writes
responses as they resolve (matched by ``id``), so a client that sends a
burst without waiting gets the full benefit of request coalescing.

Example::

    python -m repro.service --port 8642 --topology switched:8 &
    printf '%s\n' \\
      '{"id":1,"op":"register","tenant":"carA","name":"g0","graph":{...}}' \\
      '{"id":2,"op":"plan","tenant":"carA","graph":"g0"}' | nc localhost 8642
"""
from __future__ import annotations

import argparse
import asyncio
from typing import Optional

from repro.core import Topology, fully_switched_topology, paper_topology

from .protocol import (ProtocolError, Response, decode_request,
                       encode_response, spg_from_json)
from .service import SchedulerService

__all__ = ["build_service", "serve", "main"]


def _parse_topology(spec: str) -> Topology:
    if spec == "paper":
        return paper_topology()
    if spec.startswith("switched:"):
        p = int(spec.split(":", 1)[1])
        return fully_switched_topology(p, rates=[1.0] * p,
                                       link_speeds=[1.0] * p)
    raise SystemExit(f"unknown topology {spec!r} "
                     f"(expected 'paper' or 'switched:<P>')")


def build_service(args: argparse.Namespace) -> SchedulerService:
    return SchedulerService(_parse_topology(args.topology),
                            workers=args.workers, window=args.window,
                            coalesce=not args.no_coalesce)


async def _handle(service: SchedulerService,
                  reader: asyncio.StreamReader,
                  writer: asyncio.StreamWriter) -> None:
    wlock = asyncio.Lock()
    tasks = set()

    async def dispatch(line: bytes) -> None:
        rid = 0
        try:
            req = decode_request(line)
            rid = req.id
            params = dict(req.params)
            if req.op == "register" and isinstance(params.get("graph"),
                                                   dict):
                params["graph"] = spg_from_json(params["graph"])
            resp = await service.request(req.tenant, req.op, rid=rid,
                                         **params)
        except ProtocolError as e:
            resp = Response.failure(rid, "bad-request", str(e))
        except Exception as e:
            # e.g. a JSON key colliding with request()'s parameters:
            # every request line gets exactly one response, or a
            # pipelined client hangs on the missing id
            resp = Response.failure(rid, "internal",
                                    f"{type(e).__name__}: {e}")
        async with wlock:
            writer.write(encode_response(resp))
            await writer.drain()

    try:
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.strip():
                continue
            task = asyncio.ensure_future(dispatch(line))
            tasks.add(task)
            task.add_done_callback(tasks.discard)
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
    except ConnectionResetError:
        pass
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionResetError:
            pass


async def serve(service: SchedulerService, host: str,
                port: int) -> asyncio.AbstractServer:
    """Start (and return) the TCP server; callers own its lifetime."""
    return await asyncio.start_server(
        lambda r, w: _handle(service, r, w), host, port)


async def _amain(args: argparse.Namespace) -> None:
    service = build_service(args)
    try:
        server = await serve(service, args.host, args.port)
        addr = server.sockets[0].getsockname()
        print(f"repro.service listening on {addr[0]}:{addr[1]} "
              f"(workers={args.workers}, window={args.window}s, "
              f"coalesce={not args.no_coalesce})", flush=True)
        async with server:
            await server.serve_forever()
    finally:
        service.close()


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Async scheduling service over the repro.core "
                    "session API (newline-delimited JSON over TCP)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8642)
    ap.add_argument("--workers", type=int, default=4,
                    help="worker lanes (consistent-hash shards)")
    ap.add_argument("--window", type=float, default=0.002,
                    help="coalescing debounce window, seconds")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="process every request as its own batch")
    ap.add_argument("--topology", default="paper",
                    help="'paper' or 'switched:<P>'")
    args = ap.parse_args(argv)
    try:
        asyncio.run(_amain(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
