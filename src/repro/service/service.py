"""Scheduler-as-a-service: the asyncio serving front-end.

:class:`SchedulerService` wraps long-lived :class:`repro.core.Scheduler`
sessions behind an async request API for many logical clients (tenants):

  * **Request coalescing** — every request lands in its tenant's pending
    queue; a flush armed ``window`` seconds out drains the queue and
    folds adjacent same-kind runs (:mod:`repro.service.coalescing`): a
    burst of registrations becomes ONE ``submit_many`` fleet replan, a
    burst of drift updates becomes ONE batched suffix-replay
    ``Scheduler.update``.  Each request still gets its own response,
    resolved from the coalesced result.
  * **Sharding** — tenants are assigned to worker lanes by consistent
    hashing (:mod:`repro.service.sharding`); each lane serializes its
    own tenants (one ``asyncio.Lock``) and owns their Scheduler
    sessions, so independent tenants never contend on one session or
    share plan/trace caches.
  * **Graceful retiming** — drift and fault requests route through the
    exact suffix-invalidation paths of the session API;
    :class:`~repro.core.InfeasibleScheduleError` and backend demotions
    surface as structured per-request responses, never as a dead
    service.

Everything observable is deterministic: shard placement is seeded
hashing, coalescing never reorders requests, and the schedules returned
are bit-identical to a direct single-session :class:`Scheduler` replaying
the same request sequence (the chaos tests' oracle).  An *invalid*
request never poisons the burst it rode in on: items are validated
before any mutation and fail individually, and a coalesced replan that
fails outright falls back to uncoalesced per-item processing — so the
valid items of a mixed burst land exactly as they would one at a time.
The only clock reads are monotonic latency *accounting* — never a
scheduling input.

Each lane executes its batches on its own single worker thread
(``run_in_executor``), so one long replan never stalls other lanes or
the TCP accept/read loop; the per-lane ``asyncio.Lock`` plus the
one-thread executor preserve per-lane serialization, which is what the
determinism oracle needs.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import (HVLB_CC_B, FleetPlan, InfeasibleScheduleError, Plan,
                        Policy, ReplayStats, Scheduler, Topology)
from repro.core.faults import (Fault, FaultSpec, LinkDegraded, LinkDown,
                               ProcessorDown)
from repro.core.graph import SPG
from repro.core.validate import check_link_speeds, check_task_rates

from .coalescing import Batch, coalesce
from .protocol import OPS, Response
from .sharding import HashRing, shard_key

__all__ = ["SchedulerService", "ServiceClient", "ServiceError",
           "ServiceStats"]


class ServiceError(Exception):
    """A structured per-request failure (``code`` is the protocol error
    code: ``bad-request`` / ``no-graphs`` / ``infeasible`` / ...)."""

    def __init__(self, code: str, message: str) -> None:
        super().__init__(message)
        self.code = code


def _spec_as_faults(spec: FaultSpec) -> Tuple[Fault, ...]:
    """The active fault spec as constructor-ready ``Fault`` records (the
    same round-trip the chaos tests use to seed a fresh Scheduler)."""
    faults: List[Fault] = [ProcessorDown(p) for p in spec.down_procs]
    for link, f in spec.link_factors:
        faults.append(LinkDown(link) if math.isinf(f)
                      else LinkDegraded(link, f))
    return tuple(faults)


def _slice_union(union: SPG, names_sizes: Sequence[Tuple[str, int]],
                 offsets: Sequence[int]) -> List[SPG]:
    """Split a (possibly drifted) disjoint-union SPG back into per-graph
    SPGs.  Edge/tpl insertion order and every float are preserved, so
    re-unioning the slices reproduces ``union`` bit-identically — this
    is how drift applied to the fleet union survives the next
    registration burst's fresh ``submit_many``.
    """
    out: List[SPG] = []
    for (name, n), off in zip(names_sizes, offsets):
        hi = off + n
        out.append(SPG(
            n=n,
            edges=[(i - off, j - off)
                   for (i, j) in union.edges if off <= i < hi],
            weights=union.weights[off:hi].copy(),
            tpl={(i - off, j - off): v
                 for (i, j), v in union.tpl.items() if off <= i < hi},
            tpl_proportional_ccr=union.tpl_proportional_ccr,
            comp_matrix=None if union.comp_matrix is None
            else union.comp_matrix[off:hi].copy(),
            name=name))
    return out


@dataclasses.dataclass
class ServiceStats:
    """Service-level accounting (the exp10 measurements)."""

    requests: int = 0
    batches: int = 0
    replans: int = 0              # actual Scheduler invocations
    coalesced_events: int = 0     # requests folded into those replans
    plan_cache_hits: int = 0      # plan ops answered without scheduling
    errors: int = 0
    evictions: int = 0            # LRU tenant-session evictions
    replan_latencies_s: List[float] = dataclasses.field(
        default_factory=list)

    def mean_replan_latency_s(self) -> float:
        lat = self.replan_latencies_s
        return sum(lat) / len(lat) if lat else 0.0

    def p99_replan_latency_s(self) -> float:
        lat = sorted(self.replan_latencies_s)
        if not lat:
            return 0.0
        return lat[min(len(lat) - 1, max(0, math.ceil(0.99 * len(lat)) - 1))]

    def view(self) -> Dict[str, Any]:
        return {
            "requests": self.requests, "batches": self.batches,
            "replans": self.replans,
            "coalesced_events": self.coalesced_events,
            "plan_cache_hits": self.plan_cache_hits,
            "errors": self.errors, "evictions": self.evictions,
            "mean_replan_latency_s": self.mean_replan_latency_s(),
            "p99_replan_latency_s": self.p99_replan_latency_s(),
        }


@dataclasses.dataclass
class _Item:
    """One pending request: kind + params + the future its response
    resolves."""

    kind: str
    params: Dict[str, Any]
    future: "asyncio.Future[Response]"
    rid: int = 0


@dataclasses.dataclass
class _Tenant:
    """Per-tenant serving state, owned by exactly one worker lane."""

    name: str
    lane: int
    topology: Topology                       # drifts with link_speed updates
    graphs: Dict[str, SPG] = dataclasses.field(default_factory=dict)
    sched: Optional[Scheduler] = None
    fleet: Optional[FleetPlan] = None
    period: Optional[float] = None           # pinned fleet period (LRU rebuild)
    fault_records: Tuple[Fault, ...] = ()
    pending: List[_Item] = dataclasses.field(default_factory=list)
    flush_armed: bool = False
    last_used: int = 0                       # service-wide LRU tick


_FAULT_OPS = ("mark_failed", "degrade", "restore")


class SchedulerService:
    """Async scheduling service over a pool of sharded worker lanes.

    ``window`` is the coalescing debounce in seconds (``0`` = flush on
    the next event-loop tick — a synchronously-enqueued burst still
    coalesces); ``coalesce=False`` keeps the async machinery but
    processes every request as its own singleton batch (the exp10
    baseline).  ``max_tenants_per_worker`` bounds live Scheduler
    sessions per lane with LRU eviction; an evicted tenant keeps its
    graphs/faults/pinned period and is transparently rebuilt on its
    next request.
    """

    def __init__(self, topology: Topology,
                 policy: Optional[Policy] = None, *,
                 workers: int = 4, window: float = 0.0,
                 coalesce: bool = True,
                 backend: Optional[str] = None,
                 batch: Optional[int] = None,
                 max_tenants_per_worker: Optional[int] = None) -> None:
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if window < 0:
            raise ValueError(f"window must be >= 0 seconds, got {window}")
        if max_tenants_per_worker is not None and max_tenants_per_worker < 1:
            raise ValueError("max_tenants_per_worker must be >= 1")
        self.topology = topology
        self.policy = policy
        self.backend = backend
        self.batch = batch
        self.window = window
        self.coalesce = coalesce
        self.max_tenants_per_worker = max_tenants_per_worker
        self.stats = ServiceStats()
        self._topo_tag = (f"{topology.n_procs}p-"
                          f"{len(topology.all_links())}l")
        shards = [f"w{i}" for i in range(workers)]
        self._ring = HashRing(shards)
        self._lane_of = {name: i for i, name in enumerate(shards)}
        self._locks = [asyncio.Lock() for _ in range(workers)]
        self._executors: List[Optional[ThreadPoolExecutor]] = \
            [None] * workers                 # lazily, one thread per lane
        self._tenants: Dict[str, _Tenant] = {}
        # the loop inserts tenants (_tenant) while lane threads snapshot
        # the table for LRU eviction (_evict_lru); dict mutation during
        # iteration raises, so both sides take this lock
        self._tenants_lock = threading.Lock()
        self._lru_tick = 0
        # the event loop holds only weak task refs: anchor flush tasks
        # here or a GC pass could drop one mid-debounce, stranding its
        # tenant's pending futures
        self._flush_tasks: set = set()
        # stats are mutated from worker-lane threads and read from the
        # loop ("stats" op); a plain += on an int attribute is not atomic
        self._stats_lock = threading.Lock()

    # ------------------------------------------------------------ client
    def client(self, tenant: str) -> "ServiceClient":
        """An in-process client bound to one tenant."""
        return ServiceClient(self, tenant)

    def tenant_lane(self, tenant: str) -> int:
        """The worker lane that owns ``tenant`` (pure function of the
        shard key — see :func:`repro.service.sharding.shard_key`)."""
        return self._lane_of[self._ring.lookup(
            shard_key(tenant, self._topo_tag))]

    async def request(self, tenant: str, op: str,
                      rid: int = 0, **params: Any) -> Response:
        """Enqueue one request and await its (possibly coalesced)
        response.  Never raises for scheduling failures — those come
        back as ``ok=False`` responses with a structured error."""
        if op == "stats":
            with self._stats_lock:
                return Response.success(rid, self.stats.view())
        if op not in OPS:
            return Response.failure(rid, "bad-request",
                                    f"unknown op {op!r}")
        with self._stats_lock:
            self.stats.requests += 1
        t = self._tenant(tenant)
        fut: "asyncio.Future[Response]" = \
            asyncio.get_running_loop().create_future()
        t.pending.append(_Item(op, params, fut, rid))
        if not t.flush_armed:
            t.flush_armed = True
            task = asyncio.get_running_loop().create_task(
                self._flush_later(t))
            self._flush_tasks.add(task)
            task.add_done_callback(self._flush_tasks.discard)
        return await fut

    def close(self) -> None:
        """Shut down the worker-lane threads (idempotent; in-flight
        batches finish first — drain pending requests before calling)."""
        for i, ex in enumerate(self._executors):
            if ex is not None:
                ex.shutdown(wait=True)
                self._executors[i] = None

    # ----------------------------------------------------------- routing
    def _tenant(self, name: str) -> _Tenant:
        with self._tenants_lock:
            t = self._tenants.get(name)
            if t is None:
                t = _Tenant(name=name, lane=self.tenant_lane(name),
                            topology=self.topology)
                self._tenants[name] = t
        return t

    async def _flush_later(self, t: _Tenant) -> None:
        await asyncio.sleep(self.window)
        loop = asyncio.get_running_loop()
        async with self._locks[t.lane]:
            items, t.pending = t.pending, []
            t.flush_armed = False
            if not items:
                return
            if self.coalesce:
                batches = coalesce(items, lambda it: it.kind)
            else:
                batches = [Batch(it.kind, [it]) for it in items]
            self._touch(t)
            ex = self._executor(t.lane)
            for b in batches:
                # scheduling runs OFF the event loop; the lane lock +
                # one-thread executor keep per-lane serialization
                await loop.run_in_executor(ex, self._run_batch, t, b)

    def _executor(self, lane: int) -> ThreadPoolExecutor:
        ex = self._executors[lane]
        if ex is None:
            ex = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=f"repro-service-w{lane}")
            self._executors[lane] = ex
        return ex

    def _touch(self, t: _Tenant) -> None:
        self._lru_tick += 1
        t.last_used = self._lru_tick

    # --------------------------------------------------------- execution
    def _run_batch(self, t: _Tenant, batch: Batch) -> None:
        with self._stats_lock:
            self.stats.batches += 1
        try:
            if batch.kind == "register":
                self._do_register(t, batch)
            elif batch.kind == "update":
                self._do_update(t, batch)
            elif batch.kind == "plan":
                self._do_plan(t, batch)
            elif batch.kind in _FAULT_OPS:
                self._do_fault(t, batch)
            else:
                raise ServiceError("bad-request",
                                   f"unhandled op {batch.kind!r}")
        except ServiceError as e:
            self._fail(batch, e.code, str(e))
        except InfeasibleScheduleError as e:
            # no valid plan until a restore (or feasible replan): drop
            # the stale fleet so later ops rebuild instead of serving it
            t.fleet = None
            self._fail(batch, "infeasible", str(e))
        except (KeyError, TypeError, ValueError) as e:
            self._fail(batch, "bad-request", str(e))
        except Exception as e:
            # last-resort: a bug must surface as a response, never as a
            # dead flush task with clients awaiting forever
            self._fail(batch, "internal", f"{type(e).__name__}: {e}")

    def _fail(self, batch: Batch, code: str, message: str) -> None:
        for it in batch.items:
            self._fail_item(it, code, message)

    def _fail_item(self, it: _Item, code: str, message: str) -> None:
        if not it.future.done():
            with self._stats_lock:
                self.stats.errors += 1
            _set_threadsafe(it.future, Response.failure(it.rid, code,
                                                        message))

    def _resolve(self, it: _Item, result: Dict[str, Any]) -> None:
        if not it.future.done():
            _set_threadsafe(it.future, Response.success(it.rid, result))

    # -- register ------------------------------------------------------
    def _do_register(self, t: _Tenant, batch: Batch) -> None:
        # validate BEFORE mutating: an invalid item fails alone and the
        # valid items still land — exactly as they would uncoalesced
        ok: List[Tuple[_Item, str, SPG]] = []
        bad: List[Tuple[_Item, str]] = []
        taken = set(t.graphs)
        for it in batch.items:
            g = it.params.get("graph")
            if not isinstance(g, SPG):
                bad.append((it, "register needs graph=<SPG>"))
                continue
            name = it.params.get("name") or g.name
            if name in taken:
                bad.append((it, f"graph {name!r} already registered "
                                f"for tenant {t.name!r}"))
                continue
            taken.add(name)
            ok.append((it, name, g))
        if ok:
            try:
                for _, name, g in ok:
                    t.graphs[name] = g
                self._replan_fleet(t, coalesced=len(ok))
            except BaseException as e:
                for _, name, _ in ok:
                    t.graphs.pop(name, None)
                if len(batch.items) > 1 and isinstance(e, Exception):
                    # the union replan failed, but a prefix may still be
                    # feasible: fall back to uncoalesced per-item
                    # processing (bit-identical to coalesce=False; the
                    # invalid items re-fail item by item)
                    for it in batch.items:
                        self._run_batch(t, Batch(batch.kind, [it]))
                    return
                raise
            for it, name, _ in ok:
                self._resolve(it, self._graph_view(t, name))
        for it, msg in bad:
            self._fail_item(it, "bad-request", msg)

    def _replan_fleet(self, t: _Tenant, coalesced: int,
                      pin_period: bool = False) -> None:
        """One fresh ``submit_many`` over the tenant's whole graph set
        (register bursts and post-eviction rebuilds).

        ``pin_period=True`` (rebuilds over an *unchanged* graph set)
        carries the tenant's pinned fleet period into the fresh session
        so an LRU eviction stays invisible to the schedules served; a
        registration burst changes the union, so it re-derives the
        period exactly like a direct fresh ``submit_many`` would.
        """
        policy = self.policy if self.policy is not None else HVLB_CC_B()
        if pin_period and t.period is not None \
                and hasattr(policy, "period") and policy.period is None:
            policy = dataclasses.replace(policy, period=t.period)
        sched = Scheduler(t.topology, policy=policy,
                          backend=self.backend, batch=self.batch,
                          faults=t.fault_records)
        t0 = self._now()
        fleet = sched.submit_many(list(t.graphs.values()))
        self._record_replan(t0, coalesced)
        t.sched, t.fleet = sched, fleet
        t.period = fleet.period
        self._evict_lru(t.lane)

    def _require_session(self, t: _Tenant) -> Scheduler:
        if not t.graphs:
            raise ServiceError(
                "no-graphs",
                f"tenant {t.name!r} has no registered graphs")
        if t.sched is None or t.fleet is None:
            # post-eviction rebuild over the unchanged graph set
            self._replan_fleet(t, coalesced=0, pin_period=True)
        assert t.sched is not None
        return t.sched

    # -- update --------------------------------------------------------
    def _do_update(self, t: _Tenant, batch: Batch) -> None:
        sched = self._require_session(t)
        if t.fleet is None:
            raise ServiceError("internal",
                               "no fleet plan after session rebuild")
        names = list(t.graphs)
        offsets = dict(zip(names, t.fleet.offsets))
        # validate BEFORE replanning: an invalid item fails alone while
        # the valid items fold into the one suffix replay
        ok: List[_Item] = []
        bad: List[Tuple[_Item, ServiceError]] = []
        tr_events: List[Dict[int, float]] = []
        ls_events: List[Dict[str, float]] = []
        for it in batch.items:
            try:
                tr_ev, ls_ev = self._parse_update(t, it.params, names,
                                                  offsets)
            except ServiceError as e:
                bad.append((it, e))
                continue
            ok.append(it)
            if tr_ev:
                tr_events.append(tr_ev)
            if ls_ev:
                ls_events.append(ls_ev)
        if ok:
            t0 = self._now()
            try:
                plan = sched.update(task_rates=tr_events or None,
                                    link_speed=ls_events or None)
            except Exception:
                if len(batch.items) > 1:
                    # the combined replay failed; fall back to
                    # uncoalesced per-item processing so any feasible
                    # prefix still lands
                    for it in batch.items:
                        self._run_batch(t, Batch(batch.kind, [it]))
                    return
                raise
            self._record_replan(t0, coalesced=len(ok))
            self._adopt_union_plan(t, plan)
            replay = _replay_view(plan.replay)
            for it in ok:
                gname = it.params.get("graph")
                if gname is not None:
                    self._resolve(it, self._graph_view(t, gname,
                                                       replay=replay))
                else:
                    self._resolve(it, self._fleet_view(t, replay=replay))
        for it, e in bad:
            self._fail_item(it, e.code, str(e))

    def _parse_update(self, t: _Tenant, params: Dict[str, Any],
                      names: Sequence[str], offsets: Dict[str, int]
                      ) -> Tuple[Dict[int, float], Dict[str, float]]:
        """One update item's drift events in union coordinates, fully
        validated (mirrors the session API's own checks so the batched
        ``Scheduler.update`` cannot reject an item after the fact)."""
        tr_ev: Dict[int, float] = {}
        tr = params.get("task_rates")
        if tr:
            gname = params.get("graph")
            if gname is None:
                if len(names) != 1:
                    raise ServiceError(
                        "bad-request",
                        "task_rates needs graph=<name> when several "
                        "graphs are registered")
                gname = names[0]
            if gname not in offsets:
                raise ServiceError(
                    "bad-request",
                    f"unknown graph {gname!r} for tenant {t.name!r}")
            off, g = offsets[gname], t.graphs[gname]
            try:
                local = {int(task): float(f) for task, f in tr.items()}
                check_task_rates(local, g.n)
            except (TypeError, ValueError) as e:
                raise ServiceError("bad-request", str(e)) from e
            tr_ev = {off + task: f for task, f in local.items()}
        ls_ev: Dict[str, float] = {}
        ls = params.get("link_speed")
        if ls:
            try:
                ls_ev = {str(k): float(v) for k, v in ls.items()}
                check_link_speeds(ls_ev, t.topology)
            except (TypeError, ValueError) as e:
                raise ServiceError("bad-request", str(e)) from e
        return tr_ev, ls_ev

    def _adopt_union_plan(self, t: _Tenant, plan: Plan) -> None:
        """Fold a union-graph ``Plan`` back into the tenant's fleet
        state: per-graph SPGs are re-sliced from the (possibly drifted)
        union so the next registration burst re-unions bit-identically.
        """
        assert t.fleet is not None and t.sched is not None
        names_sizes = [(name, g.n) for name, g in t.graphs.items()]
        sliced = _slice_union(plan.graph, names_sizes, t.fleet.offsets)
        t.graphs = {name: g for (name, _), g in zip(names_sizes, sliced)}
        t.topology = t.sched.topology
        t.period = plan.period
        t.fleet = FleetPlan(schedule=plan.schedule, graphs=sliced,
                            offsets=list(t.fleet.offsets),
                            policy=plan.policy, period=plan.period,
                            sweep=plan.sweep, backend=plan.backend,
                            batch=plan.batch, fallback=plan.fallback)

    # -- faults --------------------------------------------------------
    def _do_fault(self, t: _Tenant, batch: Batch) -> None:
        it = batch.items[0]        # fault ops are singleton barriers
        p = it.params
        if batch.kind == "degrade" and p.get("task") is not None:
            # a compute spike addresses a task of the live fleet union,
            # so it needs a session WITH a plan: "no-graphs" before any
            # registration, transparently rebuilt after an eviction or
            # an infeasible replan (which may re-raise as "infeasible")
            sched = self._require_session(t)
        elif t.sched is None:
            # no live session (pre-registration, or evicted): record the
            # fault on a graphless session — deliberately NOT a fleet
            # rebuild first, so a restore can lift an infeasible fault
            # without having to replan under it
            t.sched = Scheduler(t.topology, policy=self.policy,
                                backend=self.backend, batch=self.batch,
                                faults=t.fault_records)
            sched = t.sched
        else:
            sched = t.sched
        t0 = self._now()
        try:
            if batch.kind == "mark_failed":
                plan = sched.mark_failed(proc=p.get("proc"),
                                         link=p.get("link"))
            elif batch.kind == "degrade":
                if p.get("task") is not None:
                    plan = sched.degrade(
                        task=self._union_task(t, p.get("graph"),
                                              int(p["task"])),
                        factor=float(p["factor"]))
                else:
                    plan = sched.degrade(link=p.get("link"),
                                         factor=float(p["factor"]))
            else:                  # restore
                plan = sched.restore(proc=p.get("proc"),
                                     link=p.get("link"))
        finally:
            # the fault stays recorded even on an infeasible replan;
            # fresh sessions (register bursts, rebuilds) must carry it
            t.fault_records = _spec_as_faults(sched.faults)
        if plan is None:
            if t.graphs:
                # the session lost its fleet (an earlier infeasible
                # replan dropped it): replan from scratch under the new
                # fault state
                self._replan_fleet(t, coalesced=len(batch),
                                   pin_period=True)
                self._resolve(it, self._fleet_view(t))
            else:                  # recorded for later registrations
                self._resolve(it, {"tenant": t.name, "deferred": True,
                                   "faults": _fault_view(sched.faults)})
            return
        self._record_replan(t0, coalesced=len(batch))
        self._adopt_union_plan(t, plan)
        self._resolve(it, self._fleet_view(
            t, replay=_replay_view(plan.replay)))

    def _union_task(self, t: _Tenant, gname: Optional[str],
                    task: int) -> int:
        if t.fleet is None:
            raise ServiceError("internal",
                               "task degrade needs a live fleet plan")
        names = list(t.graphs)
        if gname is None:
            if len(names) != 1:
                raise ServiceError(
                    "bad-request",
                    "task degrade needs graph=<name> when several "
                    "graphs are registered")
            gname = names[0]
        if gname not in t.graphs:
            raise ServiceError("bad-request",
                               f"unknown graph {gname!r} for tenant "
                               f"{t.name!r}")
        g = t.graphs[gname]
        if not 0 <= task < g.n:
            raise ServiceError(
                "bad-request",
                f"task {task} out of range for graph {gname!r} "
                f"(n={g.n})")
        return t.fleet.offsets[names.index(gname)] + task

    # -- plan ----------------------------------------------------------
    def _do_plan(self, t: _Tenant, batch: Batch) -> None:
        self._require_session(t)
        for it in batch.items:
            gname = it.params.get("graph")
            if gname is not None and gname not in t.graphs:
                # an unknown graph fails alone, not its batch-mates
                self._fail_item(it, "bad-request",
                                f"unknown graph {gname!r} for tenant "
                                f"{t.name!r}")
                continue
            with self._stats_lock:
                self.stats.plan_cache_hits += 1
            if gname is not None:
                self._resolve(it, self._graph_view(t, gname))
            else:
                self._resolve(it, self._fleet_view(t))

    # -- LRU -----------------------------------------------------------
    def _evict_lru(self, lane: int) -> None:
        cap = self.max_tenants_per_worker
        if cap is None:
            return
        # snapshot: runs on a lane thread while the loop may be
        # inserting new tenants into the dict
        with self._tenants_lock:
            snapshot = list(self._tenants.values())
        live = [t for t in snapshot
                if t.lane == lane and t.sched is not None]
        for t in sorted(live, key=lambda t: t.last_used)[:-cap]:
            # drop the session (plans, traces, compiled instances); the
            # tenant keeps graphs + faults + pinned period and is
            # rebuilt bit-identically on its next request
            t.sched, t.fleet = None, None
            with self._stats_lock:
                self.stats.evictions += 1

    # -- views ---------------------------------------------------------
    def _fleet_view(self, t: _Tenant,
                    replay: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
        f = t.fleet
        assert f is not None
        return {
            "tenant": t.name,
            "graphs": list(t.graphs),
            "makespan": float(f.makespan),
            "period": None if f.period is None else float(f.period),
            "alpha": (None if f.schedule.alpha is None
                      else float(f.schedule.alpha)),
            "backend": f.backend,
            "batch": f.batch,
            "fallback": (None if not f.fallback
                         else [list(x) for x in f.fallback]),
            "faults": _fault_view(t.sched.faults) if t.sched else None,
            "replay": replay,
        }

    def _graph_view(self, t: _Tenant, name: str,
                    replay: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
        assert t.fleet is not None
        sub = t.fleet.subschedule(list(t.graphs).index(name))
        view = self._fleet_view(t, replay=replay)
        view.update({
            "graph": name,
            "graph_makespan": float(sub.makespan),
            "proc": [int(x) for x in sub.proc],
            "start": [float(x) for x in sub.start],
            "finish": [float(x) for x in sub.finish],
        })
        return view

    # -- accounting ----------------------------------------------------
    def _now(self) -> float:
        # monotonic duration probe for latency accounting only, never a
        # scheduling input (runs on worker-lane threads, off the loop)
        return time.monotonic()

    def _record_replan(self, t0: float, coalesced: int) -> None:
        dt = self._now() - t0
        with self._stats_lock:
            self.stats.replans += 1
            self.stats.coalesced_events += coalesced
            self.stats.replan_latencies_s.append(dt)


def _set_result(fut: "asyncio.Future[Response]", resp: Response) -> None:
    if not fut.done():
        fut.set_result(resp)


def _set_threadsafe(fut: "asyncio.Future[Response]",
                    resp: Response) -> None:
    """Resolve ``fut`` from any thread: batches run on worker-lane
    threads, but an asyncio future may only be resolved on its loop."""
    fut.get_loop().call_soon_threadsafe(_set_result, fut, resp)


def _replay_view(replay: Optional[ReplayStats]
                 ) -> Optional[Dict[str, Any]]:
    if replay is None:
        return None
    return {"suffix_start": replay.suffix_start,
            "decisions_replayed": replay.decisions_replayed,
            "decisions_simulated": replay.decisions_simulated,
            "invalidated_by_fault": replay.invalidated_by_fault,
            "coalesced": replay.coalesced}


def _fault_view(spec: FaultSpec) -> Dict[str, Any]:
    return {"down_procs": list(spec.down_procs),
            "link_factors": {link: ("down" if math.isinf(f) else f)
                             for link, f in spec.link_factors}}


class ServiceClient:
    """In-process client bound to one tenant (tests/benchmarks; the TCP
    front-end in :mod:`repro.service.__main__` speaks the same ops over
    :mod:`repro.service.protocol`)."""

    def __init__(self, service: SchedulerService, tenant: str) -> None:
        self.service = service
        self.tenant = tenant

    async def register(self, graph: SPG,
                       name: Optional[str] = None) -> Response:
        return await self.service.request(
            self.tenant, "register", graph=graph, name=name)

    async def update(self, *,
                     task_rates: Optional[Dict[int, float]] = None,
                     link_speed: Optional[Dict[str, float]] = None,
                     graph: Optional[str] = None) -> Response:
        return await self.service.request(
            self.tenant, "update", task_rates=task_rates,
            link_speed=link_speed, graph=graph)

    async def mark_failed(self, *, proc: Optional[int] = None,
                          link: Optional[str] = None) -> Response:
        return await self.service.request(
            self.tenant, "mark_failed", proc=proc, link=link)

    async def degrade(self, *, link: Optional[str] = None,
                      graph: Optional[str] = None,
                      task: Optional[int] = None,
                      factor: float) -> Response:
        return await self.service.request(
            self.tenant, "degrade", link=link, graph=graph, task=task,
            factor=factor)

    async def restore(self, *, proc: Optional[int] = None,
                      link: Optional[str] = None) -> Response:
        return await self.service.request(
            self.tenant, "restore", proc=proc, link=link)

    async def plan(self, graph: Optional[str] = None) -> Response:
        return await self.service.request(self.tenant, "plan",
                                          graph=graph)
