"""Deterministic shard assignment for the serving layer.

The service spreads tenants across a pool of worker lanes, each owning
the :class:`repro.core.Scheduler` sessions (and therefore the plan/trace
caches) of the tenants assigned to it.  Assignment uses consistent
hashing so that

  * the tenant -> worker mapping is a pure function of the tenant key
    and the worker-pool shape (no registration order dependence), and
  * resizing the pool moves only ~1/N of the tenants (the classic
    consistent-hashing property) — plan caches of unaffected tenants
    survive a pool resize.

All hashing is SHA-256 based: :func:`stable_hash` is independent of
``PYTHONHASHSEED`` and of the process, so shard placement is
reproducible across runs and machines (the determinism discipline of
``repro.analysis`` extends to this package).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Sequence

__all__ = ["stable_hash", "shard_key", "HashRing"]


def stable_hash(key: str) -> int:
    """64-bit stable hash of ``key`` (first 8 bytes of SHA-256).

    Unlike the builtin ``hash``, the value does not depend on
    ``PYTHONHASHSEED`` — shard placement must be reproducible.
    """
    digest = hashlib.sha256(key.encode("utf-8")).digest()
    return int.from_bytes(digest[:8], "big")


def shard_key(tenant: str, topology_tag: str = "") -> str:
    """The cache/shard key contract (DESIGN.md §8).

    A tenant's sessions are keyed by ``tenant@topology_tag``: two
    services over different topologies place the same tenant
    independently, while within one service the key — and therefore
    the owning worker, its Scheduler session, and its plan/trace
    caches — is stable for the tenant's whole lifetime.
    """
    return f"{tenant}@{topology_tag}" if topology_tag else tenant


class HashRing:
    """Consistent-hash ring over a fixed set of shard names.

    Each shard contributes ``replicas`` virtual nodes; :meth:`lookup`
    walks clockwise from the key's hash to the next virtual node
    (``bisect`` over the sorted ring, wrap-around at the end).
    """

    def __init__(self, shards: Sequence[str], replicas: int = 64) -> None:
        if not shards:
            raise ValueError("HashRing needs at least one shard")
        if len(set(shards)) != len(shards):
            raise ValueError("HashRing shard names must be unique")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.shards: List[str] = list(shards)
        self.replicas = replicas
        points: Dict[int, str] = {}
        for name in self.shards:
            for r in range(replicas):
                points[stable_hash(f"{name}#{r}")] = name
        self._hashes: List[int] = sorted(points)
        self._owner: List[str] = [points[h] for h in self._hashes]

    def lookup(self, key: str) -> str:
        """Owning shard of ``key`` (deterministic, order-independent)."""
        h = stable_hash(key)
        i = bisect.bisect_right(self._hashes, h)
        if i == len(self._hashes):        # wrap around the ring
            i = 0
        return self._owner[i]
