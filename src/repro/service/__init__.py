"""Scheduler-as-a-service: async serving front-end over the session API.

The serving layer of DESIGN.md §8 — many logical clients (tenants)
register stream graphs, report drift and resource faults, and fetch
plans concurrently; the service coalesces request bursts into single
fleet replans / batched suffix replays and shards tenants across worker
lanes by consistent hashing.  Run a TCP front-end with
``python -m repro.service``; in-process use::

    svc = SchedulerService(paper_topology())
    client = svc.client("carA")
    resp = await client.register(graph, name="g0")
"""
from .coalescing import COALESCIBLE, Batch, coalesce
from .protocol import (ProtocolError, Request, Response, decode_request,
                       decode_response, encode_request, encode_response,
                       spg_from_json, spg_to_json)
from .service import (SchedulerService, ServiceClient, ServiceError,
                      ServiceStats)
from .sharding import HashRing, shard_key, stable_hash

__all__ = [
    "SchedulerService", "ServiceClient", "ServiceError", "ServiceStats",
    "Batch", "coalesce", "COALESCIBLE",
    "HashRing", "shard_key", "stable_hash",
    "Request", "Response", "ProtocolError",
    "encode_request", "decode_request",
    "encode_response", "decode_response",
    "spg_to_json", "spg_from_json",
]
