"""Wire protocol of the scheduler service: newline-delimited JSON.

One request per line, one response per line, matched by ``id``.  The
codec is intentionally thin — plain ``json`` over the stdlib, floats
serialized with full ``repr`` round-trip fidelity so a schedule read
back over TCP is bit-identical to the in-process plan.

Request::

    {"id": 7, "op": "register", "tenant": "carA",
     "name": "g0", "graph": {<SPG>}}
    {"id": 8, "op": "update", "tenant": "carA",
     "graph": "g0", "task_rates": {"3": 1.5}, "link_speed": {"l1": 0.5}}
    {"id": 9, "op": "mark_failed", "tenant": "carA", "proc": 2}
    {"id": 10, "op": "plan", "tenant": "carA", "graph": "g0"}

Response::

    {"id": 7, "ok": true, "result": {<plan view>}}
    {"id": 9, "ok": false,
     "error": {"code": "infeasible", "message": "..."}}

Error codes (DESIGN.md §8): ``bad-request`` (malformed arguments),
``no-graphs`` (plan/update before any register), ``infeasible``
(:class:`repro.core.InfeasibleScheduleError` — no feasible placement
under the active faults; the fault stays recorded), ``internal``.
Backend demotions are *not* errors: a demoted plan is still returned
``ok`` with the ``(from, to, reason)`` triples in ``result.fallback``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

import numpy as np

from repro.core.graph import SPG

__all__ = ["OPS", "Request", "Response", "spg_to_json", "spg_from_json",
           "encode_request", "decode_request",
           "encode_response", "decode_response", "ProtocolError"]

OPS = ("register", "update", "mark_failed", "degrade", "restore",
       "plan", "stats")


class ProtocolError(ValueError):
    """Malformed request/response payload."""


@dataclasses.dataclass
class Request:
    """One decoded client request."""

    id: int
    op: str
    tenant: str
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Response:
    """One service response (``ok`` XOR ``error``)."""

    id: int
    ok: bool
    result: Optional[Dict[str, Any]] = None
    error: Optional[Dict[str, str]] = None

    @classmethod
    def success(cls, rid: int, result: Dict[str, Any]) -> "Response":
        return cls(id=rid, ok=True, result=result)

    @classmethod
    def failure(cls, rid: int, code: str, message: str) -> "Response":
        return cls(id=rid, ok=False,
                   error={"code": code, "message": message})


# ----------------------------------------------------------------- SPG
def spg_to_json(g: SPG) -> Dict[str, Any]:
    """JSON-safe view of an SPG (exact float round-trip)."""
    return {
        "n": g.n,
        "edges": [[int(i), int(j)] for (i, j) in g.edges],
        "weights": [float(w) for w in g.weights],
        "tpl": {f"{i},{j}": float(v) for (i, j), v in g.tpl.items()},
        "ccr": g.tpl_proportional_ccr,
        "comp_matrix": (None if g.comp_matrix is None
                        else np.asarray(g.comp_matrix).tolist()),
        "name": g.name,
    }


def spg_from_json(d: Dict[str, Any]) -> SPG:
    try:
        tpl = {}
        for key, v in (d.get("tpl") or {}).items():
            i, j = key.split(",")
            tpl[(int(i), int(j))] = float(v)
        cm = d.get("comp_matrix")
        return SPG(n=int(d["n"]),
                   edges=[(int(i), int(j)) for i, j in d["edges"]],
                   weights=np.asarray(d["weights"], dtype=float),
                   tpl=tpl,
                   tpl_proportional_ccr=d.get("ccr"),
                   comp_matrix=None if cm is None
                   else np.asarray(cm, dtype=float),
                   name=str(d.get("name", "spg")))
    except ProtocolError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"malformed SPG payload: {e}") from e


# ------------------------------------------------------------- framing
def encode_request(req: Request) -> bytes:
    body = {"id": req.id, "op": req.op, "tenant": req.tenant, **req.params}
    return (json.dumps(body) + "\n").encode("utf-8")


def decode_request(line: bytes) -> Request:
    try:
        body = json.loads(line.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"not a JSON request line: {e}") from e
    if not isinstance(body, dict):
        raise ProtocolError("request must be a JSON object")
    try:
        rid = int(body.pop("id"))
        op = str(body.pop("op"))
        tenant = str(body.pop("tenant"))
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(
            f"request needs integer 'id', string 'op' and 'tenant': {e}"
        ) from e
    if op not in OPS:
        raise ProtocolError(f"unknown op {op!r} (expected one of {OPS})")
    return Request(id=rid, op=op, tenant=tenant, params=body)


def encode_response(resp: Response) -> bytes:
    body: Dict[str, Any] = {"id": resp.id, "ok": resp.ok}
    if resp.result is not None:
        body["result"] = resp.result
    if resp.error is not None:
        body["error"] = resp.error
    return (json.dumps(body) + "\n").encode("utf-8")


def decode_response(line: bytes) -> Response:
    try:
        body = json.loads(line.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"not a JSON response line: {e}") from e
    if not isinstance(body, dict) or "id" not in body or "ok" not in body:
        raise ProtocolError("response needs 'id' and 'ok'")
    return Response(id=int(body["id"]), ok=bool(body["ok"]),
                    result=body.get("result"), error=body.get("error"))
