"""Request coalescing: fold a burst of per-tenant requests into batches.

Window semantics (DESIGN.md §8): every tenant request is appended to the
tenant's pending queue and a flush is armed ``window`` seconds out (one
flush per tenant at a time — requests arriving while a flush is armed
ride the same flush).  At flush time the drained queue is split into
*adjacent runs of the same coalescible kind*:

  * a run of ``register`` requests  -> ONE ``submit_many`` of the
    tenant's whole graph set (one fleet replan instead of N),
  * a run of ``update`` requests    -> ONE batched suffix-replay
    ``Scheduler.update`` folding all the drift events
    (``ReplayStats.coalesced`` records the fold),
  * a run of ``plan`` requests      -> one cache lookup.

``mark_failed`` / ``degrade`` / ``restore`` are **barriers**: each is
its own singleton batch, executed in arrival order relative to its
neighbours.  Coalescing therefore never reorders requests — only
adjacent requests that commute by construction are folded — so the
response sequence is bit-identical to processing the queue one request
at a time (the chaos tests' oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence

__all__ = ["COALESCIBLE", "Batch", "coalesce"]

#: Request kinds that may merge with an adjacent request of the same
#: kind.  Fault operations are deliberately absent: a fault replan is a
#: barrier (its suffix invalidation depends on the exact plan it is
#: applied to, so folding across one would change observable replays).
COALESCIBLE = frozenset({"register", "update", "plan"})


@dataclasses.dataclass
class Batch:
    """One unit of scheduler work produced by :func:`coalesce`."""

    kind: str
    items: List[Any]

    def __len__(self) -> int:
        return len(self.items)


def coalesce(items: Sequence[Any],
             kind_of: Callable[[Any], str]) -> List[Batch]:
    """Split ``items`` (arrival order) into adjacent-run batches.

    Consecutive items whose ``kind_of`` is the same *coalescible* kind
    share one :class:`Batch`; every other item becomes a singleton
    batch.  The concatenation of all batches' items is exactly
    ``items`` — nothing is reordered or dropped.
    """
    out: List[Batch] = []
    for item in items:
        kind = kind_of(item)
        if (out and out[-1].kind == kind and kind in COALESCIBLE):
            out[-1].items.append(item)
        else:
            out.append(Batch(kind, [item]))
    return out
