"""Pallas kernel invariant checker — abstract interpretation of the
``pallas_call`` structure, never executing (or even importing) jax.

The batched backend carries persistent device state (link-free times,
processor-free times, loads, winner bookkeeping) across sequential grid
steps by giving those output blocks a *constant* index map: every grid
step revisits the same block, so its contents survive step-to-step.
That design is only sound under three structural invariants, which this
pass proves on the AST:

  * a carried (revisited) output block must have **exactly one**
    committed store per grid step — two stores, or a store inside a
    loop, is a write-write race once steps overlap on real hardware
    (``kernel-carried-race`` / ``kernel-carried-uncommitted``);
  * carried blocks require a *sequential* carry axis — under a 1-D grid
    the whole grid must be it; under a multi-axis grid (the (A, B)
    fused-sweep launch, DESIGN.md §5) the carry must be confined to the
    **innermost** axis: the index map names every grid axis and uses
    all leading axes to address an independent state copy per outer
    index — an under-specified index map or ``parallel`` dimension
    semantics would interleave writers (``kernel-grid-carry``);
  * block shapes must conform to the f32 TPU tile: paddings computed by
    ``pad_dim`` must target ``SUBLANE_F32`` (=8, P axis) or ``LANE``
    (=128, L axis) from layout.py (``kernel-tile-pad``).

The whole-schedule ``lax.scan`` path carries the same state as scan
*carry leaves* instead of revisited blocks, with the analogous
invariants proven on the scan body function:

  * every carried leaf must be (re)bound **exactly once** per scan step
    — a second binding, a binding inside a loop, or a duplicated name
    in the returned carry tuple aliases two writers onto one leaf
    (``scan-carry-race``);
  * every carried leaf must be bound at all — a leaf that is returned
    but never rebound silently freezes its step-0 value
    (``scan-carry-uncommitted``).  The initial ``... = carry`` unpack
    and nested function scopes (``fori_loop`` bodies run their own
    counting discipline) are excluded from the count.

Plus the dtype policy: kernels take their dtype from the refs
(``x_ref.dtype``), never from literals, so the f32/f64 switch stays a
single env-var site (``kernel-dtype``); kernel positional arity must
match in_specs+out_specs (``kernel-arity``); and the near-tie tolerance
``F32_NEAR_TIE_RTOL`` is documentation for tests, not something source
may consume (``kernel-rtol-site``).

Spec classification resolves the local helper-lambda idiom::

    full = lambda *s: pl.BlockSpec(s, lambda i: (0,) * len(s))   # carried
    dec  = lambda *s: pl.BlockSpec((1,) + s, lambda i: (i,) + …) # blocked

by testing whether the index-map lambda's first parameter appears in its
body: index maps that ignore the grid index revisit one block (carried).
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding
from .index import SourceFile

PAD_TARGETS = frozenset({"LANE", "SUBLANE_F32"})
DTYPE_LITERALS = frozenset({"float64", "float32"})
RTOL_NAME = "F32_NEAR_TIE_RTOL"

_Scope = Callable[[str], bool]

RULES: Dict[str, _Scope] = {
    "kernel-carried-race":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-carried-uncommitted":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-grid-carry":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-arity":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-tile-pad":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-dtype":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "kernel-rtol-site":
        lambda rel: rel.startswith("src/repro/"),
    "scan-carry-race":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "scan-carry-uncommitted":
        lambda rel: rel.startswith("src/repro/core/backends/"),
}


# ---------------------------------------------------------------- helpers
# (the function map and assignment environments come from the shared
# ProjectIndex — SourceFile.functions / SourceFile.assign_env)

def _is_pallas_call(node: ast.Call) -> bool:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr == "pallas_call"
    return isinstance(fn, ast.Name) and fn.id == "pallas_call"


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve(node: Optional[ast.expr],
             env: Dict[str, ast.expr]) -> Optional[ast.expr]:
    seen = set()
    while isinstance(node, ast.Name) and node.id in env \
            and node.id not in seen:
        seen.add(node.id)
        node = env[node.id]
    return node


def _lambda_param_used(lam: ast.Lambda, k: int) -> bool:
    params = [a.arg for a in lam.args.args]
    if k >= len(params):
        return False
    name = params[k]
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(lam.body))


def _lambda_uses_first_param(lam: ast.Lambda) -> bool:
    return _lambda_param_used(lam, 0)


def _spec_index_map(elem: ast.expr,
                    env: Dict[str, ast.expr]) -> Optional[ast.Lambda]:
    """The index-map lambda of one spec element (through the local
    helper-lambda idiom), or None when not statically visible."""
    blockspec: Optional[ast.Call] = None
    if isinstance(elem, ast.Call) and isinstance(elem.func, ast.Name):
        helper = _resolve(elem.func, env)
        if isinstance(helper, ast.Lambda) and isinstance(helper.body, ast.Call):
            blockspec = helper.body
    if blockspec is None and isinstance(elem, ast.Call):
        fn = elem.func
        if (isinstance(fn, ast.Attribute) and fn.attr == "BlockSpec") or \
                (isinstance(fn, ast.Name) and fn.id == "BlockSpec"):
            blockspec = elem
    if blockspec is None:
        return None
    index_map = _kw(blockspec, "index_map")
    if index_map is None and len(blockspec.args) >= 2:
        index_map = blockspec.args[1]
    if not isinstance(index_map, ast.Lambda):
        return None
    return index_map


def _spec_list(node: Optional[ast.expr],
               env: Dict[str, ast.expr]) -> Optional[List[ast.expr]]:
    node = _resolve(node, env)
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return None


def _resolve_kernel(node: Optional[ast.expr], env: Dict[str, ast.expr],
                    funcs: Dict[str, ast.FunctionDef]
                    ) -> Tuple[Optional[ast.FunctionDef], int]:
    """(kernel FunctionDef, positional args pre-bound by partial)."""
    node = _resolve(node, env)
    bound = 0
    if isinstance(node, ast.Call):            # functools.partial(kern, ...)
        fn = node.func
        is_partial = (isinstance(fn, ast.Attribute) and fn.attr == "partial") \
            or (isinstance(fn, ast.Name) and fn.id == "partial")
        if is_partial and node.args:
            bound = len(node.args) - 1        # keywords bind kw-only params
            node = _resolve(node.args[0], env)
    if isinstance(node, ast.Name):
        return funcs.get(node.id), bound
    if isinstance(node, ast.FunctionDef):
        return node, bound
    return None, bound


class _StoreCounter:
    """Counts committed stores per ref name: ``max`` over exclusive
    if/else branches, ``sum`` over straight-line code; any store under a
    loop is recorded separately (a loop store re-executes per step)."""

    def __init__(self, names: Sequence[str]) -> None:
        self.names = set(names)
        self.loop_stores: Dict[str, int] = {}

    def _stores_in(self, stmt: ast.stmt, in_loop: bool) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for tgt in targets:
                if isinstance(tgt, ast.Subscript) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id in self.names:
                    name = tgt.value.id
                    if in_loop:
                        self.loop_stores[name] = \
                            self.loop_stores.get(name, 0) + 1
                    else:
                        counts[name] = counts.get(name, 0) + 1
        elif isinstance(stmt, ast.If):
            body = self._stores_block(stmt.body, in_loop)
            orelse = self._stores_block(stmt.orelse, in_loop)
            for name in set(body) | set(orelse):
                counts[name] = max(body.get(name, 0), orelse.get(name, 0))
        elif isinstance(stmt, (ast.For, ast.While)):
            self._stores_block(stmt.body, True)
            self._stores_block(stmt.orelse, in_loop)
        elif isinstance(stmt, (ast.With, ast.Try)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                block = getattr(stmt, field, None) or []
                if field == "handlers":
                    for h in block:
                        for name, n in self._stores_block(
                                h.body, in_loop).items():
                            counts[name] = counts.get(name, 0) + n
                else:
                    for name, n in self._stores_block(
                            block, in_loop).items():
                        counts[name] = counts.get(name, 0) + n
        elif isinstance(stmt, ast.FunctionDef):
            for name, n in self._stores_block(stmt.body, in_loop).items():
                counts[name] = counts.get(name, 0) + n
        return counts

    def _stores_block(self, stmts: Sequence[ast.stmt],
                      in_loop: bool) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for stmt in stmts:
            for name, n in self._stores_in(stmt, in_loop).items():
                total[name] = total.get(name, 0) + n
        return total

    def count(self, body: Sequence[ast.stmt]) -> Dict[str, int]:
        return self._stores_block(body, False)


def _is_scan_call(node: ast.Call) -> bool:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr == "scan"
    return isinstance(fn, ast.Name) and fn.id == "scan"


def _carry_leaves(body_fn: ast.FunctionDef) -> Optional[List[str]]:
    """The carried leaf names from the scan body's ``return (carry), ys``
    (or ``return carry, ys`` with a single Name), or None when the
    carry structure is not statically visible."""
    ret: Optional[ast.Return] = None
    for stmt in body_fn.body:
        if isinstance(stmt, ast.Return):
            ret = stmt
    if ret is None or not isinstance(ret.value, ast.Tuple) \
            or len(ret.value.elts) != 2:
        return None
    carry = ret.value.elts[0]
    if isinstance(carry, ast.Name):
        return [carry.id]
    if isinstance(carry, ast.Tuple) \
            and all(isinstance(e, ast.Name) for e in carry.elts):
        return [e.id for e in carry.elts]  # type: ignore[union-attr]
    return None


class _NameBindCounter:
    """Counts (re)bindings per carried leaf name inside a scan body:
    ``max`` over exclusive if/else branches, ``sum`` over straight-line
    code; a binding under a loop is recorded separately (it re-executes
    per iteration).  Nested function scopes are *skipped* — an inner
    ``fori_loop`` body threads its own state tuple and is not a write
    to the outer leaf.  The initial ``... = <carry-param>`` unpack is
    excluded (it reads the previous step's carry, it does not commit
    this step's)."""

    def __init__(self, names: Sequence[str],
                 exclude_value_name: Optional[str]) -> None:
        self.names = set(names)
        self.exclude = exclude_value_name
        self.loop_stores: Dict[str, int] = {}

    def _bind_targets(self, tgt: ast.expr) -> List[str]:
        if isinstance(tgt, ast.Name) and tgt.id in self.names:
            return [tgt.id]
        if isinstance(tgt, (ast.Tuple, ast.List)):
            out: List[str] = []
            for e in tgt.elts:
                out.extend(self._bind_targets(e))
            return out
        return []

    def _binds_in(self, stmt: ast.stmt, in_loop: bool) -> Dict[str, int]:
        counts: Dict[str, int] = {}

        def add(name: str) -> None:
            if in_loop:
                self.loop_stores[name] = self.loop_stores.get(name, 0) + 1
            else:
                counts[name] = counts.get(name, 0) + 1

        if isinstance(stmt, ast.Assign):
            if isinstance(stmt.value, ast.Name) \
                    and stmt.value.id == self.exclude:
                return counts                 # the initial carry unpack
            for tgt in stmt.targets:
                for name in self._bind_targets(tgt):
                    add(name)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            for name in self._bind_targets(stmt.target):
                add(name)
        elif isinstance(stmt, ast.If):
            body = self._binds_block(stmt.body, in_loop)
            orelse = self._binds_block(stmt.orelse, in_loop)
            for name in set(body) | set(orelse):
                counts[name] = max(body.get(name, 0), orelse.get(name, 0))
        elif isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                for name in self._bind_targets(stmt.target):
                    self.loop_stores[name] = \
                        self.loop_stores.get(name, 0) + 1
            self._binds_block(stmt.body, True)
            self._binds_block(stmt.orelse, in_loop)
        elif isinstance(stmt, (ast.With, ast.Try)):
            for field in ("body", "orelse", "finalbody"):
                for name, n in self._binds_block(
                        getattr(stmt, field, None) or [], in_loop).items():
                    counts[name] = counts.get(name, 0) + n
        # nested FunctionDef / AsyncFunctionDef: different scope, skipped
        return counts

    def _binds_block(self, stmts: Sequence[ast.stmt],
                     in_loop: bool) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for stmt in stmts:
            for name, n in self._binds_in(stmt, in_loop).items():
                total[name] = total.get(name, 0) + n
        return total

    def count(self, body: Sequence[ast.stmt]) -> Dict[str, int]:
        return self._binds_block(body, False)


def _check_scan(path: str, call: ast.Call,
                funcs: Dict[str, ast.FunctionDef]) -> List[Finding]:
    """Scan-carry aliasing rules over one ``lax.scan(body, ...)`` call
    (module docstring): each carried leaf rebound exactly once per step,
    no duplicate names in the returned carry tuple."""
    out: List[Finding] = []
    body_expr = call.args[0] if call.args else _kw(call, "f")
    body = funcs.get(body_expr.id) \
        if isinstance(body_expr, ast.Name) else None
    if body is None:
        return out                    # structure not statically visible
    leaves = _carry_leaves(body)
    if leaves is None:
        return out
    dupes = {n for n in leaves if leaves.count(n) > 1}
    for name in sorted(dupes):
        out.append(Finding(
            "scan-carry-race", path, body.lineno,
            f"carry leaf {name} appears {leaves.count(name)} times in "
            f"{body.name}'s returned carry tuple — two carry positions "
            f"alias one binding"))
    carry_param = body.args.args[0].arg if body.args.args else None
    counter = _NameBindCounter(leaves, carry_param)
    counts = counter.count(body.body)
    for name in dict.fromkeys(leaves):        # unique, order-preserving
        if name in dupes:
            continue
        looped = counter.loop_stores.get(name, 0)
        top = counts.get(name, 0)
        if looped:
            out.append(Finding(
                "scan-carry-race", path, body.lineno,
                f"carry leaf {name} is rebound inside a loop in "
                f"{body.name} — carried state must be committed exactly "
                f"once per scan step"))
        elif top > 1:
            out.append(Finding(
                "scan-carry-race", path, body.lineno,
                f"carry leaf {name} has {top} bindings per scan step in "
                f"{body.name} — intermediate values of carried state "
                f"must live under different names"))
        elif top == 0:
            out.append(Finding(
                "scan-carry-uncommitted", path, body.lineno,
                f"carry leaf {name} is returned by {body.name} but never "
                f"rebound — the leaf silently freezes its initial value"))
    return out


def _grid_ndim(call: ast.Call, env: Dict[str, ast.expr]) -> Optional[int]:
    grid = _resolve(_kw(call, "grid"), env)
    if isinstance(grid, ast.Tuple):
        return len(grid.elts)
    if isinstance(grid, (ast.Constant, ast.Name)):
        return 1                              # grid=B scalar form
    return None


def _has_parallel_semantics(call: ast.Call) -> bool:
    return any(isinstance(n, ast.Constant) and n.value == "parallel"
               for kw in call.keywords
               for n in ast.walk(kw.value))


# ------------------------------------------------------------------ pass

def _check_call(path: str, call: ast.Call, env: Dict[str, ast.expr],
                funcs: Dict[str, ast.FunctionDef]) -> List[Finding]:
    out: List[Finding] = []
    kernel_expr = call.args[0] if call.args else _kw(call, "kernel")
    kernel, bound = _resolve_kernel(kernel_expr, env, funcs)
    in_specs = _spec_list(_kw(call, "in_specs"), env)
    out_specs = _spec_list(_kw(call, "out_specs"), env)
    if kernel is None or in_specs is None or out_specs is None:
        return out                            # structure not statically visible

    n_in, n_out = len(in_specs), len(out_specs)
    params = [a.arg for a in kernel.args.args][bound:]
    if _kw(call, "scratch_shapes") is None and len(params) != n_in + n_out:
        out.append(Finding(
            "kernel-arity", path, call.lineno,
            f"kernel {kernel.name} takes {len(params)} positional refs but "
            f"in_specs+out_specs supply {n_in}+{n_out}={n_in + n_out}"))
        return out                            # spec->param map is meaningless

    # a block is "carried" when it is revisited across the sequential
    # (innermost) grid axis: under a 1-D grid the index map ignores its
    # only param; under a multi-axis grid it ignores the LAST param
    # (or has too few params to even name that axis).
    ndim = _grid_ndim(call, env)
    multi = ndim is not None and ndim > 1
    carried_out = []
    for i, spec in enumerate(out_specs):
        lam = _spec_index_map(spec, env)
        if lam is None:
            continue
        if multi:
            n_params = len(lam.args.args)
            revisited = n_params < ndim or \
                not _lambda_param_used(lam, ndim - 1)
        else:
            revisited = not _lambda_uses_first_param(lam)
        if revisited:
            carried_out.append((i, params[n_in + i]))

    if carried_out:
        if multi:
            # multi-axis grid semantics (the (A, B) sweep launch): a
            # carried block is sound iff its carry is confined to the
            # innermost (sequential) axis — the index map must name
            # every grid axis and use all LEADING axes, so each outer
            # index addresses its own independent state copy; only the
            # last axis may be ignored (revisited).
            for i, name in carried_out:
                lam = _spec_index_map(out_specs[i], env)
                n_params = 0 if lam is None else len(lam.args.args)
                if lam is not None and n_params >= ndim and \
                        all(_lambda_param_used(lam, k)
                            for k in range(ndim - 1)):
                    continue
                out.append(Finding(
                    "kernel-grid-carry", path, call.lineno,
                    f"carried output block {name} under a {ndim}-D grid "
                    f"whose index map does not address every leading "
                    f"grid axis — outer steps would interleave writers "
                    f"on one block (the (A, B) sweep contract carries "
                    f"only on the innermost axis)"))
        if _has_parallel_semantics(call):
            out.append(Finding(
                "kernel-grid-carry", path, call.lineno,
                "carried output blocks with 'parallel' dimension "
                "semantics — grid steps would interleave writers"))

    counter = _StoreCounter([name for _, name in carried_out])
    counts = counter.count(kernel.body)
    for _, name in carried_out:
        top = counts.get(name, 0)
        looped = counter.loop_stores.get(name, 0)
        if looped:
            out.append(Finding(
                "kernel-carried-race", path, kernel.lineno,
                f"carried block {name} is stored inside a loop — carried "
                f"state must be committed exactly once per grid step"))
        elif top > 1:
            out.append(Finding(
                "kernel-carried-race", path, kernel.lineno,
                f"carried block {name} has {top} committed stores per grid "
                f"step — write-write race across sequential revisits"))
        elif top == 0:
            out.append(Finding(
                "kernel-carried-uncommitted", path, kernel.lineno,
                f"carried block {name} is never stored — its revisited "
                f"contents would be whatever the previous step left"))

    # dtype policy inside the kernel body
    for node in ast.walk(kernel):
        if isinstance(node, ast.Attribute) and node.attr in DTYPE_LITERALS:
            out.append(Finding(
                "kernel-dtype", path, node.lineno,
                f"dtype literal .{node.attr} inside kernel {kernel.name} — "
                f"derive the dtype from a ref (.dtype) so the f32/f64 "
                f"switch stays one site"))
        elif isinstance(node, ast.Constant) and node.value in DTYPE_LITERALS:
            out.append(Finding(
                "kernel-dtype", path, node.lineno,
                f"dtype string {node.value!r} inside kernel {kernel.name} — "
                f"derive the dtype from a ref (.dtype)"))
    return out


def run(sf: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    path, tree = sf.display, sf.tree
    funcs = sf.functions
    module_env = sf.assign_env()

    # function scopes first (their local spec/kernel assignments shadow
    # module ones); whatever remains is a module-level pallas_call
    checked_kernels = set()
    scopes: List[ast.AST] = [fn for fn in ast.walk(tree)
                             if isinstance(fn, (ast.FunctionDef,
                                                ast.AsyncFunctionDef))]
    scopes.append(tree)
    for scope in scopes:
        env = dict(module_env)
        if scope is not tree:
            env.update(sf.assign_env(scope))
        for node in ast.walk(scope):
            if isinstance(node, ast.Call) and _is_pallas_call(node) \
                    and id(node) not in checked_kernels:
                checked_kernels.add(id(node))
                out.extend(_check_call(path, node, env, funcs))

    # scan-carry discipline over every lax.scan body in the file
    checked_scans = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_scan_call(node) \
                and id(node) not in checked_scans:
            checked_scans.add(id(node))
            out.extend(_check_scan(path, node, funcs))

    # tile-padding conformance: pad_dim targets must be the layout
    # constants (or 1 = no padding), anywhere in the file
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "pad_dim" and len(node.args) >= 2:
            mult = node.args[1]
            ok = (isinstance(mult, ast.Name) and mult.id in PAD_TARGETS) or \
                 (isinstance(mult, ast.Constant) and mult.value == 1)
            if not ok:
                out.append(Finding(
                    "kernel-tile-pad", path, node.lineno,
                    "pad_dim multiple must be layout.SUBLANE_F32 (P axis) "
                    "or layout.LANE (L axis) — ad-hoc paddings break the "
                    "f32 TPU tile"))

    # F32_NEAR_TIE_RTOL: definition site only; source must not consume it
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == RTOL_NAME \
                and isinstance(node.ctx, ast.Load):
            out.append(Finding(
                "kernel-rtol-site", path, node.lineno,
                f"{RTOL_NAME} consumed in source — it documents the "
                f"near-tie band for tests; decisions must not branch on it"))
    return out
