"""Static invariant analyzer for the scheduling engine.

Four pure-AST passes (no jax/numpy import, nothing executed) over one
shared :class:`~repro.analysis.index.ProjectIndex` (each file parsed
exactly once): :mod:`~repro.analysis.kernels` proves the Pallas
carried-state and tile layout invariants, :mod:`~repro.analysis.lint`
enforces the bit-exactness/determinism contract of the decision layer,
:mod:`~repro.analysis.typing_gate` checks every backend against the
``CandidateEvaluator`` protocol, and
:mod:`~repro.analysis.concurrency` proves the service layer's hybrid
asyncio/thread locking discipline.  Run with ``python -m
repro.analysis`` (``--format=json`` for machine-readable findings); see
DESIGN.md §7 for the invariant catalogue and findings schema.
"""
from .cli import ALL_RULES, main
from .findings import Finding
from .index import ProjectIndex, SourceFile

__all__ = ["ALL_RULES", "Finding", "ProjectIndex", "SourceFile", "main"]
