"""Static invariant analyzer for the scheduling engine.

Three pure-AST passes (no jax/numpy import, nothing executed):
:mod:`~repro.analysis.kernels` proves the Pallas carried-state and tile
layout invariants, :mod:`~repro.analysis.lint` enforces the
bit-exactness/determinism contract of the decision layer, and
:mod:`~repro.analysis.typing_gate` checks every backend against the
``CandidateEvaluator`` protocol.  Run with ``python -m repro.analysis``;
see DESIGN.md §7 for the invariant catalogue.
"""
from .cli import ALL_RULES, main
from .findings import Finding

__all__ = ["ALL_RULES", "Finding", "main"]
