"""Protocol/typing gate for ``CandidateEvaluator`` backends (pure-AST).

A new backend that forgets ``evaluate_batch`` or renames a parameter
must fail at analysis time, not at the first scheduled wave.  The gate
parses ``backends/base.py`` for the protocol (abstract methods +
signatures) and checks every subclass found in the scanned files:

  protocol-missing     an abstract protocol method is not implemented
  protocol-signature   an overridden method's positional parameters
                       disagree with the protocol (extra trailing
                       parameters are fine only with defaults — callers
                       hold a base-typed reference)
  backend-name         a concrete backend lacks the ``name`` class
                       attribute the registry keys on

The deeper annotation check (strict mypy over base.py/layout.py/
__init__.py, config in mypy.ini) runs in the CI analysis job where mypy
is installable; :func:`maybe_run_mypy` shells out when mypy is on PATH
and skips gracefully when it is not, so ``python -m repro.analysis``
stays dependency-free.
"""
from __future__ import annotations

import ast
import shutil
import subprocess
from typing import Callable, Dict, List, Optional

from .findings import Finding
from .index import ProjectIndex

BASE_CLASS = "CandidateEvaluator"

_Scope = Callable[[str], bool]

RULES: Dict[str, _Scope] = {
    "protocol-missing":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "protocol-signature":
        lambda rel: rel.startswith("src/repro/core/backends/"),
    "backend-name":
        lambda rel: rel.startswith("src/repro/core/backends/"),
}


class _Method:
    def __init__(self, node: ast.FunctionDef) -> None:
        self.name = node.name
        self.args = [a.arg for a in node.args.args]
        self.n_defaults = len(node.args.defaults)
        self.abstract = any(
            (isinstance(d, ast.Name) and d.id == "abstractmethod")
            or (isinstance(d, ast.Attribute) and d.attr == "abstractmethod")
            for d in node.decorator_list)
        self.static = any(
            isinstance(d, ast.Name) and d.id == "staticmethod"
            for d in node.decorator_list)


def _methods(cls: ast.ClassDef) -> Dict[str, _Method]:
    return {n.name: _Method(n) for n in cls.body
            if isinstance(n, ast.FunctionDef)}


def _has_name_attr(cls: ast.ClassDef) -> bool:
    for node in cls.body:
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == "name"
                   for t in node.targets):
                return True
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name) \
                    and node.target.id == "name" and node.value is not None:
                return True
    return False


def _subclasses_of(classes: List[ast.ClassDef],
                   base: str) -> List[ast.ClassDef]:
    out = []
    for node in classes:
        if node.name != base:
            for b in node.bases:
                if (isinstance(b, ast.Name) and b.id == base) or \
                        (isinstance(b, ast.Attribute) and b.attr == base):
                    out.append(node)
                    break
    return out


def _find_base(index: ProjectIndex) -> Optional[ast.ClassDef]:
    for sf in index.files.values():
        for node in sf.classes:
            if node.name == BASE_CLASS:
                return node
    return None


def run(index: ProjectIndex) -> List[Finding]:
    """Cross-file pass over the shared index, which must include the
    file defining :data:`BASE_CLASS` for the gate to have a protocol to
    check against (otherwise: no findings)."""
    base_cls = _find_base(index)
    if base_cls is None:
        return []
    protocol = _methods(base_cls)
    out: List[Finding] = []

    for path, sf in index.files.items():
        for cls in _subclasses_of(sf.classes, BASE_CLASS):
            impl = _methods(cls)
            if not _has_name_attr(cls):
                out.append(Finding(
                    "backend-name", path, cls.lineno,
                    f"backend {cls.name} has no 'name' class attribute — "
                    f"the BACKENDS registry and Plan.fallback key on it"))
            for meth in protocol.values():
                if meth.abstract and meth.name not in impl:
                    out.append(Finding(
                        "protocol-missing", path, cls.lineno,
                        f"backend {cls.name} does not implement abstract "
                        f"protocol method {meth.name}"))
            for meth_name, got in impl.items():
                want = protocol.get(meth_name)
                if want is None:
                    continue
                if got.args[:len(want.args)] != want.args:
                    out.append(Finding(
                        "protocol-signature", path, cls.lineno,
                        f"{cls.name}.{meth_name}({', '.join(got.args)}) "
                        f"disagrees with the protocol signature "
                        f"({', '.join(want.args)})"))
                    continue
                extra = len(got.args) - len(want.args)
                if extra > got.n_defaults:
                    out.append(Finding(
                        "protocol-signature", path, cls.lineno,
                        f"{cls.name}.{meth_name} adds {extra} positional "
                        f"parameter(s) without defaults — callers hold a "
                        f"{BASE_CLASS}-typed reference and won't pass them"))
    return out


def maybe_run_mypy(repo_root: str) -> Optional[str]:
    """Run the scoped strict-mypy gate if mypy is installed; return its
    output on failure, ``""`` on success, ``None`` when unavailable."""
    if shutil.which("mypy") is None:
        return None
    proc = subprocess.run(
        ["mypy", "--config-file", "mypy.ini"],
        cwd=repo_root, capture_output=True, text=True)
    return "" if proc.returncode == 0 else proc.stdout + proc.stderr
