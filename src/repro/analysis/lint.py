"""Bit-exactness & determinism lint (pure-AST, never imports the code).

The engine's contract is that the *decision layer* is exact integer /
comparison logic and every float op happens inside a backend — that is
what makes scalar/vector/pallas decisions bit-identical.  These rules
encode that contract plus the determinism hygiene the chaos oracle
relies on:

  float-arith         decision layer (engine.py / api.py) performs float
                      arithmetic outside backend calls
  sentinel-scope      fault sentinels referenced outside faults.py and
                      the engine masking point
  nondeterminism      time.time / unseeded legacy random in repro.core
  set-iteration       direct iteration over a set (order is hash-seed
                      dependent) without sorted(...)
  deprecation-route   warnings.warn(DeprecationWarning) outside
                      deprecation.warn_once
  host-sync           device_get / block_until_ready in backends outside
                      the documented one-per-wave transfer
  unused-import       dead imports in repro.core (excl. __init__.py
                      re-export surfaces)

Each rule carries a repo-mode path scope; in explicit-path (fixture)
mode every rule applies to every given file.
"""
from __future__ import annotations

import ast
import re
from typing import Callable, Dict, List, Set

from .findings import Finding
from .index import SourceFile

SENTINELS = frozenset({"DOWN_COMP", "DOWN_SPEED", "INFEASIBLE_EFT"})
FLOAT_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.Pow,
             ast.FloorDiv, ast.Mod)
BANNED_TIME = frozenset({"time", "time_ns"})      # monotonic et al. fine
BANNED_RANDOM = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "seed", "getrandbits", "gauss", "normalvariate"})
LEGACY_NP_RANDOM = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "choice", "shuffle", "permutation", "uniform", "normal"})
HOST_SYNCS = frozenset({"device_get", "block_until_ready"})

_Scope = Callable[[str], bool]


def _in(prefix: str) -> _Scope:
    return lambda rel: rel.startswith(prefix)


def _core_not(*basenames: str) -> _Scope:
    return lambda rel: (rel.startswith("src/repro/core/")
                        and rel.rsplit("/", 1)[-1] not in basenames)


def _sched_pkgs(rel: str) -> bool:
    """The deterministic scheduling surface: the core engine AND the
    async serving layer on top of it (repro.service) — both must stay
    reproducible for the chaos/bit-identity oracles to hold."""
    return (rel.startswith("src/repro/core/")
            or rel.startswith("src/repro/service/"))


#: rule-id -> repo-mode scope predicate over repo-relative posix paths
RULES: Dict[str, _Scope] = {
    "float-arith": lambda rel: rel in ("src/repro/core/engine.py",
                                       "src/repro/core/api.py"),
    "sentinel-scope": _core_not("faults.py", "engine.py"),
    "nondeterminism": _sched_pkgs,
    "set-iteration": _sched_pkgs,
    "deprecation-route": lambda rel: (rel.startswith("src/repro/")
                                      and rel != "src/repro/core/deprecation.py"),
    "host-sync": _in("src/repro/core/backends/"),
    "unused-import": lambda rel: (_sched_pkgs(rel)
                                  and rel.rsplit("/", 1)[-1] != "__init__.py"),
}


def _module_float_consts(tree: ast.Module) -> Set[str]:
    """Names bound at module level to a bare float literal."""
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, float):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _is_float_operand(node: ast.expr, float_names: Set[str]) -> bool:
    if isinstance(node, ast.UnaryOp):
        node = node.operand
    if isinstance(node, ast.Constant) and isinstance(node.value, float):
        return True
    return isinstance(node, ast.Name) and node.id in float_names


def _check_float_arith(path: str, tree: ast.Module) -> List[Finding]:
    consts = _module_float_consts(tree)
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, FLOAT_OPS) \
                and (_is_float_operand(node.left, consts)
                     or _is_float_operand(node.right, consts)):
            out.append(Finding(
                "float-arith", path, node.lineno,
                "float arithmetic in the decision layer — move it into a "
                "backend, or justify the site with an allow pragma"))
    return out


def _check_sentinel_scope(path: str, tree: ast.Module) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        name = None
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in SENTINELS:
            name = node.id
        elif isinstance(node, ast.Attribute) and node.attr in SENTINELS:
            name = node.attr
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in SENTINELS:
                    out.append(Finding(
                        "sentinel-scope", path, node.lineno,
                        f"sentinel {alias.name} imported outside faults.py "
                        f"and the engine masking point"))
            continue
        if name is not None:
            out.append(Finding(
                "sentinel-scope", path, node.lineno,
                f"sentinel {name} referenced outside faults.py and the "
                f"engine masking point"))
    return out


def _check_nondeterminism(path: str, tree: ast.Module) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            val = node.value
            if isinstance(val, ast.Name) and val.id == "time" \
                    and node.attr in BANNED_TIME:
                out.append(Finding(
                    "nondeterminism", path, node.lineno,
                    f"time.{node.attr} is wall-clock dependent — use "
                    f"time.monotonic/perf_counter for durations"))
            elif isinstance(val, ast.Name) and val.id == "random" \
                    and node.attr in BANNED_RANDOM:
                out.append(Finding(
                    "nondeterminism", path, node.lineno,
                    f"global random.{node.attr} depends on interpreter-wide "
                    f"state — use a seeded np.random.Generator"))
            elif isinstance(val, ast.Attribute) and val.attr == "random" \
                    and isinstance(val.value, ast.Name) \
                    and val.value.id in ("np", "numpy") \
                    and node.attr in LEGACY_NP_RANDOM:
                out.append(Finding(
                    "nondeterminism", path, node.lineno,
                    f"legacy np.random.{node.attr} uses the global "
                    f"RandomState — use np.random.default_rng(seed)"))
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "time" \
                and not (isinstance(node.func.value, ast.Name)
                         and node.func.value.id == "time"):
            # loop.time() / self._loop.time(): the asyncio event-loop
            # clock (time.time() itself is caught by the branch above)
            out.append(Finding(
                "nondeterminism", path, node.lineno,
                "event-loop clock read (.time()) — scheduling decisions "
                "must not depend on it; latency accounting needs a "
                "justified allow pragma"))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time":
                for alias in node.names:
                    if alias.name in BANNED_TIME:
                        out.append(Finding(
                            "nondeterminism", path, node.lineno,
                            f"from time import {alias.name} — wall-clock "
                            f"dependent"))
            elif node.module == "random":
                out.append(Finding(
                    "nondeterminism", path, node.lineno,
                    "importing from the global random module — use a "
                    "seeded np.random.Generator"))
    return out


def _is_set_expr(node: ast.expr) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


def _check_set_iteration(path: str, tree: ast.Module) -> List[Finding]:
    out = []

    def flag(node: ast.expr) -> None:
        out.append(Finding(
            "set-iteration", path, node.lineno,
            "iteration order over a set is hash-seed dependent — wrap in "
            "sorted(...) to keep decisions reproducible"))

    for node in ast.walk(tree):
        if isinstance(node, ast.For) and _is_set_expr(node.iter):
            flag(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    flag(gen.iter)
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "tuple") and node.args \
                and _is_set_expr(node.args[0]):
            flag(node.args[0])
    return out


def _check_deprecation_route(path: str, tree: ast.Module) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        is_warn = (isinstance(fn, ast.Attribute) and fn.attr == "warn") or \
                  (isinstance(fn, ast.Name) and fn.id == "warn")
        if not is_warn:
            continue
        mentions = any(isinstance(sub, ast.Name)
                       and sub.id == "DeprecationWarning"
                       for arg in list(node.args)
                       + [kw.value for kw in node.keywords]
                       for sub in ast.walk(arg))
        if mentions:
            out.append(Finding(
                "deprecation-route", path, node.lineno,
                "DeprecationWarning raised directly — route through "
                "deprecation.warn_once so -W error CI stays quiet and the "
                "warning fires once per process"))
    return out


def _check_host_sync(path: str, tree: ast.Module) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in HOST_SYNCS:
            out.append(Finding(
                "host-sync", path, node.lineno,
                f"host sync {node.func.attr} in a backend — only the "
                f"documented one-per-wave transfer may block on the device"))
    return out


_WORD = re.compile(r"\w+")


def _check_unused_import(path: str, tree: ast.Module) -> List[Finding]:
    imported: Dict[str, int] = {}          # bound name -> lineno
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                imported[bound] = node.lineno
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                imported[alias.asname or alias.name] = node.lineno

    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and not isinstance(node.ctx, ast.Store):
            used.add(node.id)
    # quoted annotations and __all__ keep a name alive
    for node in ast.walk(tree):
        ann = None
        if isinstance(node, ast.AnnAssign):
            ann = node.annotation
        elif isinstance(node, ast.arg):
            ann = node.annotation
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            ann = node.returns
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            used.update(_WORD.findall(ann.value))
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant) \
                                and isinstance(elt.value, str):
                            used.add(elt.value)
    # string annotations anywhere (e.g. "CompiledInstance" under
    # TYPE_CHECKING) are covered above; plain docstrings are not scanned
    # so prose mentions cannot keep a dead import alive.
    return [Finding("unused-import", path, lineno,
                    f"import {name!r} is unused")
            for name, lineno in sorted(imported.items(), key=lambda kv: kv[1])
            if name not in used]


_CHECKS = {
    "float-arith": _check_float_arith,
    "sentinel-scope": _check_sentinel_scope,
    "nondeterminism": _check_nondeterminism,
    "set-iteration": _check_set_iteration,
    "deprecation-route": _check_deprecation_route,
    "host-sync": _check_host_sync,
    "unused-import": _check_unused_import,
}


def run(sf: SourceFile) -> List[Finding]:
    """All lint findings for one indexed file (scope-agnostic — the CLI
    applies repo-mode path scopes)."""
    out: List[Finding] = []
    for check in _CHECKS.values():
        out.extend(check(sf.display, sf.tree))
    return out
