"""Shared per-file analysis index: one parse + symbol tables for all passes.

Every analyzer pass used to carry its own ``ast.parse`` and its own
little symbol helpers; with a fourth pass (``concurrency``) that cost
would be paid four times per file.  :class:`ProjectIndex` centralizes
it: each file is read and parsed **exactly once** (``parse_count`` is
test-pinned), and the derived tables the passes share — function map,
class list, assignment environments — are computed lazily on the
:class:`SourceFile` and cached, so kernels/lint/typing-gate/concurrency
all consume the same objects.

The tables deliberately mirror the historical helpers' semantics (e.g.
:meth:`SourceFile.assign_env` is the kernel pass's flat
last-assignment-wins scan, nested statements included) so the refactor
is behavior-preserving: the passes produce byte-identical findings.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class SourceFile:
    """One parsed source file plus lazily-built shared symbol tables."""

    path: Path
    display: str               # path as reported in findings
    text: str
    lines: List[str]
    tree: ast.Module
    _functions: Optional[Dict[str, ast.FunctionDef]] = None
    _classes: Optional[List[ast.ClassDef]] = None
    _assign_envs: Optional[Dict[int, Dict[str, ast.expr]]] = None
    _import_origins: Optional[Dict[str, str]] = None

    @property
    def functions(self) -> Dict[str, ast.FunctionDef]:
        """name -> (sync) FunctionDef, whole file, nested included
        (last definition wins — the kernel pass's resolution order)."""
        if self._functions is None:
            self._functions = {
                node.name: node for node in ast.walk(self.tree)
                if isinstance(node, ast.FunctionDef)}
        return self._functions

    @property
    def classes(self) -> List[ast.ClassDef]:
        """Every ClassDef in the file, in AST walk order."""
        if self._classes is None:
            self._classes = [node for node in ast.walk(self.tree)
                             if isinstance(node, ast.ClassDef)]
        return self._classes

    def assign_env(self, scope: Optional[ast.AST] = None
                   ) -> Dict[str, ast.expr]:
        """name -> value for single-target Name assignments under
        ``scope`` (default: the module), nested statements included,
        last assignment wins.  Cached per scope."""
        scope = scope if scope is not None else self.tree
        if self._assign_envs is None:
            self._assign_envs = {}
        cached = self._assign_envs.get(id(scope))
        if cached is None:
            cached = {}
            for node in ast.walk(scope):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    cached[node.targets[0].id] = node.value
            self._assign_envs[id(scope)] = cached
        return cached

    @property
    def import_origins(self) -> Dict[str, str]:
        """bound name -> dotted origin (``"threading.Lock"``,
        ``"asyncio"``, ...) for every import in the file."""
        if self._import_origins is None:
            origins: Dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        bound = alias.asname or alias.name.split(".")[0]
                        origins[bound] = alias.name
                elif isinstance(node, ast.ImportFrom):
                    mod = node.module or ""
                    for alias in node.names:
                        if alias.name == "*":
                            continue
                        origins[alias.asname or alias.name] = \
                            f"{mod}.{alias.name}" if mod else alias.name
            self._import_origins = origins
        return self._import_origins


class ProjectIndex:
    """All files of one analyzer invocation, each parsed exactly once.

    ``load`` returns the cached :class:`SourceFile` on a repeated path,
    so no matter how many passes (or how many times one pass) ask for a
    file, ``parse_count`` equals the number of distinct files.
    Unreadable/unparsable files land in ``errors`` (the CLI turns those
    into exit code 2) and are not retried.
    """

    def __init__(self) -> None:
        self.files: Dict[str, SourceFile] = {}     # display -> SourceFile
        self.errors: List[str] = []
        self.parse_count = 0
        self._failed: set = set()

    def load(self, path: Path, display: str) -> Optional[SourceFile]:
        sf = self.files.get(display)
        if sf is not None:
            return sf
        if display in self._failed:
            return None
        try:
            text = path.read_text(encoding="utf-8")
        except OSError as e:
            self.errors.append(f"cannot read {path}: {e}")
            self._failed.add(display)
            return None
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError as e:
            self.errors.append(f"{path}:{e.lineno}: syntax error: {e.msg}")
            self._failed.add(display)
            return None
        self.parse_count += 1
        sf = SourceFile(path=path, display=display, text=text,
                        lines=text.splitlines(), tree=tree)
        self.files[display] = sf
        return sf

    def trees(self) -> List[Tuple[str, ast.Module]]:
        """``(display, tree)`` pairs in load order (the cross-file
        passes' iteration surface)."""
        return [(sf.display, sf.tree) for sf in self.files.values()]
