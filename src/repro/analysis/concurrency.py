"""Concurrency race detector for the hybrid asyncio/thread service layer.

``repro.service`` mixes four concurrency regimes on purpose: asyncio
coroutines on the event loop, per-lane single-thread executors for
replans, a ``threading.Lock`` around cross-thread stats, and
``call_soon_threadsafe`` to resolve loop-owned futures from worker
threads.  That discipline is sound (DESIGN.md §8) but fragile under
maintenance — a stats counter bumped outside the lock or a replan
called straight from a coroutine corrupts tenants silently.  This pass
proves the discipline statically, pure-AST and stdlib-only, the way
:mod:`~repro.analysis.kernels` proves the Pallas carried-state shape.

The model, per module:

  * every function/method is a node in a **call graph** (``self.m()``
    and bare-name calls resolve within the module);
  * **loop context** seeds at every ``async def`` and every callback
    handed to ``call_soon``/``call_soon_threadsafe``/``call_later``/
    ``add_done_callback``; **worker context** seeds at every callable
    submitted to an executor (``run_in_executor``, ``Executor.submit``,
    ``asyncio.to_thread``, ``threading.Thread(target=...)``).  Contexts
    propagate through sync call edges, so a helper called from both
    sides carries both;
  * per class, attributes assigned in ``__init__`` form the **ownership
    map**: attributes classified as locks (``threading.Lock``/``RLock``
    vs ``asyncio.Lock`` — scalars or collections) and executors, the
    rest as candidate shared state.  Lock *regions* are the lexical
    bodies of ``with``/``async with`` whose context expression resolves
    to a lock attribute — through subscripts (``self._locks[lane]``)
    and local aliases (``lock = self._stats_lock``).

Rules:

  race-unguarded-shared    a mutable attribute touched from both loop
                           and worker context has an access site that
                           does not hold its owning lock (the lock held
                           at the majority of guarded sites)
  race-await-under-lock    ``await`` (incl. ``async with``/``async
                           for``, e.g. a lane-lock acquisition) while a
                           ``threading.Lock`` is held — the loop and
                           every contender stall until release
  loop-blocking-call       blocking work in loop context: ``time.sleep``,
                           ``Future.result()``, or a direct
                           ``Scheduler.submit/submit_many/update/...``
                           replan that bypasses the lane executor
  race-cross-thread-future ``set_result``/``set_exception`` called from
                           worker context — loop-owned futures resolve
                           only via ``call_soon_threadsafe``
  leak-executor            a ``ThreadPoolExecutor`` (class attribute or
                           local) that no method ever shuts down
  gc-task-ref              a ``create_task``/``ensure_future`` task that
                           is not strongly referenced (the loop keeps
                           only weak refs; a GC pass can drop it
                           mid-debounce — the PR 9 ``_flush_later`` bug
                           as a rule)

Heuristics are deliberately name- and structure-based (a receiver is
"a Scheduler" if it is constructed from ``Scheduler(...)`` or named
``sched``/``scheduler``); a site that is correct by design carries an
``# analysis: allow[rule] reason`` pragma like every other pass.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .index import SourceFile

_Scope = Callable[[str], bool]

#: repo-mode scope: the async serving layer (extend the prefix list when
#: a new async/threaded package lands — the analyzer must grow with it)
_ASYNC_PKGS = ("src/repro/service/",)


def _svc(rel: str) -> bool:
    return rel.startswith(_ASYNC_PKGS)


RULES: Dict[str, _Scope] = {
    "race-unguarded-shared": _svc,
    "race-await-under-lock": _svc,
    "loop-blocking-call": _svc,
    "race-cross-thread-future": _svc,
    "leak-executor": _svc,
    "gc-task-ref": _svc,
}

THREAD_LOCKS = frozenset({"Lock", "RLock"})
ASYNC_LOCKS = frozenset({"Lock", "Condition", "Semaphore", "BoundedSemaphore"})
EXECUTORS = frozenset({"ThreadPoolExecutor", "ProcessPoolExecutor"})
#: method names that mutate their receiver in place
MUTATORS = frozenset({
    "append", "add", "extend", "insert", "remove", "discard", "pop",
    "popitem", "clear", "update", "setdefault", "sort", "appendleft"})
#: blocking Scheduler session ops (replans) — loop code must route them
#: through the lane executor
SCHED_OPS = frozenset({"submit", "submit_many", "update", "probe_update",
                       "mark_failed", "degrade", "restore"})
SCHED_NAMES = frozenset({"sched", "scheduler", "_sched", "_scheduler"})
EXECUTOR_NAMES = frozenset({"ex", "executor", "pool", "_ex", "_executor"})
TASK_MAKERS = frozenset({"create_task", "ensure_future"})
ANCHOR_METHODS = frozenset({"add", "append", "insert"})
AWAITER_FUNCS = frozenset({"gather", "wait", "as_completed", "shield"})

LockId = Tuple[str, str]            # ("thread"|"async", attr-or-site key)


# ------------------------------------------------------------ small helpers

def _terminal_name(expr: ast.expr) -> Optional[str]:
    """The last identifier of a Name/Attribute/Subscript chain."""
    while isinstance(expr, ast.Subscript):
        expr = expr.value
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _self_attr(expr: ast.expr) -> Optional[str]:
    """``attr`` if ``expr`` is exactly ``self.attr``."""
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
            and expr.value.id == "self":
        return expr.attr
    return None


def _self_root(expr: ast.expr) -> Optional[str]:
    """The attribute a chain is rooted at: ``self.X[...].m`` -> ``X``."""
    while True:
        attr = _self_attr(expr)
        if attr is not None:
            return attr
        if isinstance(expr, ast.Attribute):
            expr = expr.value
        elif isinstance(expr, (ast.Subscript, ast.Starred)):
            expr = expr.value
        elif isinstance(expr, ast.Call):
            expr = expr.func
        else:
            return None


def _resolve_local(expr: Optional[ast.expr], env: Dict[str, ast.expr]
                   ) -> Optional[ast.expr]:
    seen: Set[str] = set()
    while isinstance(expr, ast.Name) and expr.id in env \
            and expr.id not in seen:
        seen.add(expr.id)
        expr = env[expr.id]
    return expr


def _call_name(call: ast.Call) -> Optional[str]:
    """The called name: ``f(...)`` -> f, ``a.b.f(...)`` -> f."""
    return _terminal_name(call.func)


def _is_ctor(expr: Optional[ast.expr], names: FrozenSet[str],
             origins: Dict[str, str], module: str) -> bool:
    """Is ``expr`` a call constructing one of ``names`` (checked against
    the import origins when the name was imported from somewhere)?"""
    if not isinstance(expr, ast.Call):
        return False
    name = _call_name(expr)
    if name not in names:
        return False
    fn = expr.func
    if isinstance(fn, ast.Name):
        origin = origins.get(fn.id, "")
        return origin == "" or origin.startswith(module) or origin == fn.id
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
        base = fn.value.id
        return origins.get(base, base).split(".")[0] == module.split(".")[0]
    return True


@dataclasses.dataclass
class _Func:
    node: ast.AST                    # FunctionDef | AsyncFunctionDef
    qname: str
    cls: Optional[ast.ClassDef]
    is_async: bool
    contexts: Set[str] = dataclasses.field(default_factory=set)
    edges: Set[int] = dataclasses.field(default_factory=set)   # callee ids
    accesses: List["_Access"] = dataclasses.field(default_factory=list)
    blocking: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    resolves: List[Tuple[int, str]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class _Access:
    attr: str
    line: int
    write: bool
    held: FrozenSet[LockId]


@dataclasses.dataclass
class _ClassInfo:
    node: ast.ClassDef
    init_attrs: Set[str] = dataclasses.field(default_factory=set)
    locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    executors: Dict[str, int] = dataclasses.field(default_factory=dict)
    methods: Dict[str, _Func] = dataclasses.field(default_factory=dict)


class _ModuleAnalysis:
    """One file's concurrency model: call graph, contexts, ownership."""

    def __init__(self, sf: SourceFile) -> None:
        self.sf = sf
        self.origins = sf.import_origins
        self.funcs: Dict[int, _Func] = {}          # id(node) -> _Func
        self.by_name: Dict[str, _Func] = {}        # bare-name resolution
        self.classes: List[_ClassInfo] = []
        self.loop_seeds: Set[int] = set()
        self.worker_seeds: Set[int] = set()
        self.findings: List[Finding] = []

    # -------------------------------------------------- registry building
    def build(self) -> None:
        for stmt in self.sf.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._register(stmt, cls=None, prefix="")
            elif isinstance(stmt, ast.ClassDef):
                info = _ClassInfo(stmt)
                self.classes.append(info)
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        f = self._register(sub, cls=stmt,
                                           prefix=stmt.name + ".")
                        info.methods[sub.name] = f
                self._classify_attrs(info)
        for info in self.classes:
            self._find_executor_stores(info)

    def _register(self, node: ast.AST, cls: Optional[ast.ClassDef],
                  prefix: str) -> _Func:
        f = _Func(node=node, qname=prefix + node.name, cls=cls,
                  is_async=isinstance(node, ast.AsyncFunctionDef))
        self.funcs[id(node)] = f
        # module-level names win bare-name resolution; nested defs are
        # still reachable when their name is unique in the file
        if cls is None and (node.name not in self.by_name or not prefix):
            self.by_name[node.name] = f
        for sub in ast.walk(node):
            if sub is not node and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(sub) not in self.funcs:
                self._register(sub, cls=cls, prefix=f.qname + ".")
        return f

    def _classify_attrs(self, info: _ClassInfo) -> None:
        init = info.methods.get("__init__")
        if init is None:
            return
        for stmt in ast.walk(init.node):
            targets: List[ast.expr] = []
            value: Optional[ast.expr] = None
            if isinstance(stmt, ast.Assign):
                targets, value = list(stmt.targets), stmt.value
            elif isinstance(stmt, ast.AnnAssign):
                targets, value = [stmt.target], stmt.value
            else:
                continue
            for tgt in targets:
                elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                for elt in elts:
                    attr = _self_attr(elt)
                    if attr is None:
                        continue
                    info.init_attrs.add(attr)
                    kind = self._lock_kind_of_value(value)
                    if kind is not None:
                        info.locks[attr] = kind
                    if value is not None and self._contains_executor(value):
                        info.executors.setdefault(attr, elt.lineno)

    def _lock_kind_of_value(self, value: Optional[ast.expr]
                            ) -> Optional[str]:
        """'thread' / 'async' if ``value`` constructs (or is a
        collection of) lock primitives."""
        if value is None:
            return None
        for node in ast.walk(value):
            if _is_ctor(node, THREAD_LOCKS, self.origins, "threading"):
                return "thread"
            if _is_ctor(node, ASYNC_LOCKS, self.origins, "asyncio"):
                return "async"
        return None

    def _contains_executor(self, value: ast.expr) -> bool:
        return any(_is_ctor(n, EXECUTORS, self.origins, "concurrent")
                   for n in ast.walk(value))

    def _find_executor_stores(self, info: _ClassInfo) -> None:
        """Executors created outside ``__init__`` and stored on self
        (the lazy-creation idiom) also count as executor attributes."""
        for f in info.methods.values():
            env = self.sf.assign_env(f.node)
            for stmt in ast.walk(f.node):
                if not isinstance(stmt, ast.Assign):
                    continue
                if not self._contains_executor_resolved(stmt.value, env):
                    continue
                for tgt in stmt.targets:
                    attr = _self_root(tgt)
                    if attr is not None:
                        info.executors.setdefault(attr, stmt.lineno)
                        info.init_attrs.add(attr)

    def _contains_executor_resolved(self, value: ast.expr,
                                    env: Dict[str, ast.expr]) -> bool:
        resolved = _resolve_local(value, env)
        return resolved is not None and self._contains_executor(resolved)

    # ------------------------------------------------------ function scans
    def scan_all(self) -> None:
        for f in list(self.funcs.values()):
            _FuncScan(self, f).scan()

    # ------------------------------------------------- context propagation
    def propagate(self) -> None:
        for f in self.funcs.values():
            if f.is_async:
                f.contexts.add("loop")
        for fid in self.loop_seeds:
            self.funcs[fid].contexts.add("loop")
        for fid in self.worker_seeds:
            self.funcs[fid].contexts.add("worker")
        changed = True
        while changed:
            changed = False
            for f in self.funcs.values():
                for callee_id in f.edges:
                    g = self.funcs.get(callee_id)
                    if g is None or g.is_async:
                        continue          # calling an async def makes a
                    for ctx in f.contexts:  # coroutine, not a transfer
                        if ctx not in g.contexts:
                            g.contexts.add(ctx)
                            changed = True

    # ------------------------------------------------------- rule evaluation
    def evaluate(self) -> List[Finding]:
        path = self.sf.display
        for f in self.funcs.values():
            if "loop" in f.contexts:
                for line, msg in f.blocking:
                    self.findings.append(Finding(
                        "loop-blocking-call", path, line, msg))
            if "worker" in f.contexts:
                for line, meth in f.resolves:
                    self.findings.append(Finding(
                        "race-cross-thread-future", path, line,
                        f"{meth}() called from worker context — a "
                        f"loop-owned future may only be resolved on its "
                        f"loop; route it through "
                        f"fut.get_loop().call_soon_threadsafe(...)"))
        for info in self.classes:
            self._evaluate_ownership(info)
            self._evaluate_executors(info)
        return self.findings

    def _evaluate_ownership(self, info: _ClassInfo) -> None:
        path = self.sf.display
        sites: Dict[str, List[Tuple[_Access, _Func]]] = {}
        for f in info.methods.values():
            if f.node.name == "__init__" or not f.contexts:
                continue
            for acc in f.accesses:
                if acc.attr in info.init_attrs \
                        and acc.attr not in info.locks:
                    sites.setdefault(acc.attr, []).append((acc, f))
        for attr in sorted(sites):
            recs = sites[attr]
            ctxs: Set[str] = set()
            for _, f in recs:
                ctxs |= f.contexts
            if not ({"loop", "worker"} <= ctxs):
                continue                  # single-regime attribute
            if not any(acc.write for acc, _ in recs):
                continue                  # never mutated post-init
            by_line: Dict[int, Tuple[_Access, _Func]] = {}
            for acc, f in recs:           # merge read+write at one line
                prev = by_line.get(acc.line)
                if prev is None or (acc.write and not prev[0].write):
                    by_line[acc.line] = (acc, f)
            guarded = [acc for acc, _ in by_line.values() if acc.held]
            owner: Optional[LockId] = None
            if guarded:
                counts: Dict[LockId, int] = {}
                for acc in guarded:
                    for lock in acc.held:
                        counts[lock] = counts.get(lock, 0) + 1
                owner = sorted(counts, key=lambda k: (-counts[k], k))[0]
            for line in sorted(by_line):
                acc, f = by_line[line]
                if owner is not None and owner in acc.held:
                    continue
                where = ("both loop and worker contexts"
                         if f.contexts >= {"loop", "worker"}
                         else "the event loop" if "loop" in f.contexts
                         else "a worker thread")
                if owner is None:
                    msg = (f"shared attribute self.{attr} is mutated "
                           f"across loop and worker threads but no "
                           f"access holds a lock — give it an owning "
                           f"lock and guard every site")
                else:
                    msg = (f"shared attribute self.{attr} accessed from "
                           f"{where} without its owning lock "
                           f"self.{owner[1]}")
                self.findings.append(Finding(
                    "race-unguarded-shared", path, line, msg))

    def _evaluate_executors(self, info: _ClassInfo) -> None:
        for attr in sorted(info.executors):
            joined = False
            for f in info.methods.values():
                has_shutdown = any(
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "shutdown"
                    for n in ast.walk(f.node))
                mentions = any(_self_attr(n) == attr
                               for n in ast.walk(f.node)
                               if isinstance(n, ast.Attribute))
                if has_shutdown and mentions:
                    joined = True
                    break
            if not joined:
                self.findings.append(Finding(
                    "leak-executor", self.sf.display, info.executors[attr],
                    f"ThreadPoolExecutor stored on self.{attr} is never "
                    f"shut down — join it in close() so worker threads "
                    f"cannot outlive the service"))


class _FuncScan:
    """One function's body walk: lock regions, accesses, call edges,
    entry registrations, and the lexical rules (2 and 6)."""

    def __init__(self, mod: _ModuleAnalysis, f: _Func) -> None:
        self.mod = mod
        self.f = f
        self.env = mod.sf.assign_env(f.node)
        self.held: List[LockId] = []

    # lock ids currently held, restricted to thread locks
    def _thread_locks(self) -> List[LockId]:
        return [lock for lock in self.held if lock[0] == "thread"]

    def scan(self) -> None:
        self._scan_stmts(self.f.node.body)
        self._scan_tasks()
        self._scan_local_executors()

    # ----------------------------------------------------------- statements
    def _scan_stmts(self, stmts: Sequence[ast.stmt]) -> None:
        for stmt in stmts:
            self._scan_stmt(stmt)

    def _scan_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                        # separate scan unit
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            self._scan_with(stmt)
            return
        if isinstance(stmt, ast.AsyncFor):
            self._rule2(stmt.lineno, "async for")
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for tgt in targets:
                for node in ast.walk(tgt):
                    attr = _self_attr(node) if isinstance(
                        node, ast.Attribute) else None
                    if attr is not None:
                        self._record(attr, node.lineno, write=True)
        # child expressions at this statement level
        for field, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                self._scan_expr(value)
            elif isinstance(value, list):
                if value and isinstance(value[0], ast.stmt):
                    self._scan_stmts(value)
                else:
                    for item in value:
                        if isinstance(item, ast.expr):
                            self._scan_expr(item)
                        elif isinstance(item, ast.excepthandler):
                            self._scan_stmts(item.body)
                        elif isinstance(item, ast.withitem):
                            pass          # handled in _scan_with
                        elif hasattr(item, "body") \
                                and isinstance(getattr(item, "body"),
                                               list):  # match cases
                            self._scan_stmts(item.body)

    def _scan_with(self, stmt: ast.stmt) -> None:
        acquired: List[LockId] = []
        for item in stmt.items:
            self._scan_expr(item.context_expr)
            lock = self._lock_of(item.context_expr)
            if lock is not None:
                acquired.append(lock)
        if isinstance(stmt, ast.AsyncWith):
            self._rule2(stmt.lineno, "async with (lock acquisition)")
        self.held.extend(acquired)
        try:
            self._scan_stmts(stmt.body)
        finally:
            del self.held[len(self.held) - len(acquired):]

    def _lock_of(self, expr: ast.expr) -> Optional[LockId]:
        resolved = _resolve_local(expr, self.env)
        if resolved is None:
            return None
        while isinstance(resolved, ast.Subscript):
            resolved = _resolve_local(resolved.value, self.env)
        attr = _self_attr(resolved) if isinstance(resolved, ast.Attribute) \
            else None
        if attr is not None and self.f.cls is not None:
            info = next((c for c in self.mod.classes
                         if c.node is self.f.cls), None)
            if info is not None and attr in info.locks:
                return (info.locks[attr], attr)
        kind = self.mod._lock_kind_of_value(resolved) \
            if isinstance(resolved, ast.Call) else None
        if kind is not None:
            name = expr.id if isinstance(expr, ast.Name) \
                else f"line-{resolved.lineno}"
            return (kind, name)
        return None

    def _rule2(self, lineno: int, what: str) -> None:
        locks = self._thread_locks()
        if locks:
            self.mod.findings.append(Finding(
                "race-await-under-lock", self.mod.sf.display, lineno,
                f"{what} while holding threading lock "
                f"self.{locks[-1][1]} — the event loop and every "
                f"contender stall until it releases"))

    # ---------------------------------------------------------- expressions
    def _scan_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Await):
                self._rule2(node.lineno, "await")
            elif isinstance(node, ast.Attribute):
                attr = _self_attr(node)
                if attr is not None:
                    self._record(attr, node.lineno,
                                 write=isinstance(node.ctx,
                                                  (ast.Store, ast.Del)))
            elif isinstance(node, ast.Call):
                self._scan_call(node)

    def _record(self, attr: str, lineno: int, write: bool) -> None:
        self.f.accesses.append(_Access(
            attr=attr, line=lineno, write=write,
            held=frozenset(self.held)))

    def _scan_call(self, call: ast.Call) -> None:
        fn = call.func
        name = _call_name(call)
        # in-place mutation of a self-rooted chain counts as a write
        if isinstance(fn, ast.Attribute) and fn.attr in MUTATORS:
            root = _self_root(fn.value)
            if root is not None:
                self._record(root, call.lineno, write=True)
        # --- entry registrations -------------------------------------
        if name == "run_in_executor" and len(call.args) >= 2:
            self._mark_entry(call.args[1], "worker")
        elif name == "to_thread" and call.args:
            self._mark_entry(call.args[0], "worker")
        elif name == "submit" and isinstance(fn, ast.Attribute) \
                and self._executorish(fn.value) and call.args:
            self._mark_entry(call.args[0], "worker")
        elif name == "Thread" and _is_ctor(call, frozenset({"Thread"}),
                                           self.mod.origins, "threading"):
            for kw in call.keywords:
                if kw.arg == "target":
                    self._mark_entry(kw.value, "worker")
        elif name in ("call_soon", "call_soon_threadsafe") and call.args:
            self._mark_entry(call.args[0], "loop")
        elif name == "call_later" and len(call.args) >= 2:
            self._mark_entry(call.args[1], "loop")
        elif name == "add_done_callback" and call.args:
            self._mark_entry(call.args[0], "loop")
        # --- call edges ----------------------------------------------
        if isinstance(fn, ast.Attribute):
            if isinstance(fn.value, ast.Name) and fn.value.id == "self":
                callee = self._method(fn.attr)
                if callee is not None:
                    self.f.edges.add(id(callee.node))
        elif isinstance(fn, ast.Name):
            callee = self.mod.by_name.get(fn.id)
            if callee is not None:
                self.f.edges.add(id(callee.node))
        # --- rule 3: blocking candidates -----------------------------
        if isinstance(fn, ast.Attribute):
            if fn.attr == "sleep" and isinstance(fn.value, ast.Name) \
                    and self.mod.origins.get(fn.value.id,
                                             fn.value.id) == "time":
                self.f.blocking.append((
                    call.lineno,
                    "time.sleep blocks the event loop — use "
                    "await asyncio.sleep (or run it on an executor)"))
            elif fn.attr == "result" and not call.args:
                self.f.blocking.append((
                    call.lineno,
                    "Future.result() blocks the event loop until the "
                    "future resolves — await it instead"))
            elif fn.attr in SCHED_OPS and self._schedish(fn.value):
                self.f.blocking.append((
                    call.lineno,
                    f"Scheduler.{fn.attr} called from event-loop "
                    f"context — replans must run on a worker lane "
                    f"(run_in_executor), or the loop stalls for the "
                    f"whole replan"))
            # --- rule 4: cross-thread future resolution --------------
            if fn.attr in ("set_result", "set_exception"):
                recv = _terminal_name(fn.value) or "future"
                self.f.resolves.append((call.lineno,
                                        f"{recv}.{fn.attr}"))
        elif isinstance(fn, ast.Name) and fn.id == "sleep" \
                and self.mod.origins.get(fn.id) == "time.sleep":
            self.f.blocking.append((
                call.lineno,
                "time.sleep blocks the event loop — use "
                "await asyncio.sleep (or run it on an executor)"))

    def _method(self, name: str) -> Optional[_Func]:
        if self.f.cls is None:
            return None
        info = next((c for c in self.mod.classes
                     if c.node is self.f.cls), None)
        return info.methods.get(name) if info is not None else None

    def _mark_entry(self, expr: ast.expr, ctx: str) -> None:
        if isinstance(expr, ast.Call) and _call_name(expr) == "partial":
            if expr.args:
                self._mark_entry(expr.args[0], ctx)
            return
        target: Optional[_Func] = None
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            target = self._method(expr.attr)
        elif isinstance(expr, ast.Name):
            resolved = _resolve_local(expr, self.env)
            if isinstance(resolved, ast.Name):
                target = self.mod.by_name.get(resolved.id)
            else:
                target = self.mod.by_name.get(expr.id)
        if target is not None:
            seeds = self.mod.worker_seeds if ctx == "worker" \
                else self.mod.loop_seeds
            seeds.add(id(target.node))

    def _executorish(self, recv: ast.expr) -> bool:
        resolved = _resolve_local(recv, self.env)
        if resolved is not None and self.mod._contains_executor(resolved):
            return True
        root = _self_root(recv)
        if root is not None and self.f.cls is not None:
            info = next((c for c in self.mod.classes
                         if c.node is self.f.cls), None)
            if info is not None and root in info.executors:
                return True
        name = _terminal_name(recv)
        return name in EXECUTOR_NAMES if name else False

    def _schedish(self, recv: ast.expr) -> bool:
        resolved = _resolve_local(recv, self.env)
        if isinstance(resolved, ast.Call) \
                and _call_name(resolved) == "Scheduler":
            return True
        name = _terminal_name(recv)
        return name in SCHED_NAMES if name else False

    # ------------------------------------------------------ rule 6: tasks
    def _scan_tasks(self) -> None:
        body_stmts = [s for s in ast.walk(self.f.node)
                      if isinstance(s, ast.stmt)]
        for stmt in body_stmts:
            if isinstance(stmt, ast.Expr) and self._task_call(stmt.value):
                self._flag_task(stmt.value.lineno)
            elif isinstance(stmt, ast.Assign) \
                    and self._task_call(stmt.value):
                if len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    if not self._anchored(stmt.targets[0].id):
                        self._flag_task(stmt.value.lineno)
                # attribute/subscript targets are themselves anchors

    def _task_call(self, expr: ast.expr) -> bool:
        return isinstance(expr, ast.Call) \
            and _call_name(expr) in TASK_MAKERS

    def _anchored(self, name: str) -> bool:
        for node in ast.walk(self.f.node):
            if isinstance(node, ast.Call):
                fn = node.func
                arg_names = [a.id for a in node.args
                             if isinstance(a, ast.Name)]
                if isinstance(fn, ast.Attribute) \
                        and fn.attr in ANCHOR_METHODS \
                        and name in arg_names:
                    return True
                if _call_name(node) in AWAITER_FUNCS \
                        and name in arg_names:
                    return True
            elif isinstance(node, ast.Assign):
                if isinstance(node.value, ast.Name) \
                        and node.value.id == name \
                        and any(isinstance(t, (ast.Attribute, ast.Subscript))
                                for t in node.targets):
                    return True
            elif isinstance(node, ast.Await):
                if isinstance(node.value, ast.Name) \
                        and node.value.id == name:
                    return True
            elif isinstance(node, ast.Return):
                if isinstance(node.value, ast.Name) \
                        and node.value.id == name:
                    return True
        return False

    def _flag_task(self, lineno: int) -> None:
        self.mod.findings.append(Finding(
            "gc-task-ref", self.mod.sf.display, lineno,
            "task is not strongly referenced — the event loop keeps "
            "only weak task refs, so a GC pass can drop it mid-flight; "
            "anchor it in a container until its done-callback discards "
            "it"))

    # ------------------------------------------- rule 5: local executors
    def _scan_local_executors(self) -> None:
        for stmt in ast.walk(self.f.node):
            if not isinstance(stmt, ast.Assign) \
                    or len(stmt.targets) != 1 \
                    or not isinstance(stmt.targets[0], ast.Name):
                continue
            if not _is_ctor(stmt.value, EXECUTORS, self.mod.origins,
                            "concurrent"):
                continue
            name = stmt.targets[0].id
            if not self._local_executor_escapes(name):
                self.mod.findings.append(Finding(
                    "leak-executor", self.mod.sf.display, stmt.lineno,
                    f"local ThreadPoolExecutor {name!r} is never shut "
                    f"down — use 'with {name}:' or call "
                    f"{name}.shutdown()"))

    def _local_executor_escapes(self, name: str) -> bool:
        for node in ast.walk(self.f.node):
            if isinstance(node, ast.Call):
                fn = node.func
                if isinstance(fn, ast.Attribute) \
                        and fn.attr == "shutdown" \
                        and isinstance(fn.value, ast.Name) \
                        and fn.value.id == name:
                    return True
                if any(isinstance(a, ast.Name) and a.id == name
                       for a in node.args):
                    return True           # handed to another owner
            elif isinstance(node, ast.withitem):
                ce = node.context_expr
                if isinstance(ce, ast.Name) and ce.id == name:
                    return True
            elif isinstance(node, ast.Assign):
                if isinstance(node.value, ast.Name) \
                        and node.value.id == name \
                        and any(isinstance(t, (ast.Attribute, ast.Subscript))
                                for t in node.targets):
                    return True
            elif isinstance(node, ast.Return):
                if isinstance(node.value, ast.Name) \
                        and node.value.id == name:
                    return True
        return False


def run(sf: SourceFile) -> List[Finding]:
    """All concurrency findings for one indexed file (scope-agnostic —
    the CLI applies repo-mode path scopes)."""
    mod = _ModuleAnalysis(sf)
    mod.build()
    mod.scan_all()
    mod.propagate()
    return mod.evaluate()
