"""``python -m repro.analysis`` — static invariant analyzer entry point.

Two modes:

  * **repo mode** (no paths): scan ``src/repro`` with each rule confined
    to its repo scope (kernel rules to ``core/backends/``, decision-layer
    float lint to ``engine.py``/``api.py``, concurrency rules to
    ``service/``, …) and apply the committed ratchet baseline
    ``analysis-baseline.txt`` at the repo root.  ``--paths`` narrows the
    scan to matching path prefixes without changing rule scoping.
  * **explicit mode** (paths given): apply *every* rule to exactly those
    files (directories expand to their ``*.py`` trees; the file list is
    sorted and deduplicated) with no default baseline — this is what the
    fixture tests use to demonstrate each rule.

All passes share one :class:`~repro.analysis.index.ProjectIndex`, so
each file is read and parsed exactly once no matter how many passes
consume it.

Exit codes: 0 clean, 1 findings (or stale baseline entries — the
ratchet only tightens), 2 broken invocation (missing file, syntax
error, unknown rule).  Findings print as ``path:line: [rule] msg``, or
with ``--format=json`` as one JSON object per line carrying ``rule``,
``path``, ``line``, ``source`` (the stripped source line), the
suppression ``fingerprint``, and ``message`` — machine-readable for CI
artifacts and dashboards.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import concurrency, kernels, lint, typing_gate
from .findings import (Finding, apply_baseline, apply_pragmas, fingerprint,
                       load_baseline)
from .index import ProjectIndex

#: every rule the analyzer knows, with its repo-mode path scope
ALL_RULES = {**lint.RULES, **kernels.RULES, **typing_gate.RULES,
             **concurrency.RULES}

_REPO_ROOT = Path(__file__).resolve().parents[3]
_SRC_ROOT = Path(__file__).resolve().parents[1]        # src/repro
DEFAULT_BASELINE = "analysis-baseline.txt"


def _repo_files() -> List[Tuple[Path, str]]:
    out = []
    for p in sorted(_SRC_ROOT.rglob("*.py")):
        rel = p.relative_to(_REPO_ROOT).as_posix()
        if rel.startswith("src/repro/analysis/"):
            continue                  # the analyzer does not police itself
        out.append((p, rel))
    return out


def _explicit_files(raw_paths: Sequence[str]
                    ) -> Tuple[List[Tuple[Path, str]], Optional[str]]:
    """Expand/sort/dedupe positional paths.  Directories contribute
    their ``*.py`` tree; overlapping arguments (``pkg pkg/mod.py``, a
    file named twice) analyze once.  Returns (files, error)."""
    collected: List[Tuple[Path, str]] = []
    for raw in raw_paths:
        p = Path(raw)
        if p.is_dir():
            for sub in sorted(p.rglob("*.py")):
                collected.append((sub, sub.as_posix()))
        elif p.is_file():
            collected.append((p, raw))
        else:
            return [], f"no such file or directory: {raw}"
    seen: Set[Path] = set()
    files: List[Tuple[Path, str]] = []
    for p, display in sorted(collected, key=lambda t: t[1]):
        resolved = p.resolve()
        if resolved in seen:
            continue
        seen.add(resolved)
        files.append((p, display))
    return files, None


def _collect(files: Sequence[Tuple[Path, str]], repo_mode: bool,
             rules: Optional[set],
             ) -> Tuple[List[Finding], Dict[str, List[str]], List[str]]:
    index = ProjectIndex()
    findings: List[Finding] = []
    for path, display in files:
        sf = index.load(path, display)
        if sf is None:
            continue
        findings.extend(lint.run(sf))
        findings.extend(kernels.run(sf))
        findings.extend(concurrency.run(sf))
    findings.extend(typing_gate.run(index))
    lines_of = {sf.display: sf.lines for sf in index.files.values()}

    if repo_mode:
        findings = [f for f in findings
                    if f.rule not in ALL_RULES or ALL_RULES[f.rule](f.path)]
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    findings = apply_pragmas(findings, lines_of)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, lines_of, index.errors


def _finding_json(f: Finding, fp: str, lines: List[str]) -> str:
    source = lines[f.line - 1].strip() if 0 < f.line <= len(lines) else ""
    return json.dumps({"rule": f.rule, "path": f.path, "line": f.line,
                       "source": source, "fingerprint": fp,
                       "message": f.message})


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant analyzer (kernel races/layout, "
                    "bit-exactness lint, backend protocol gate, "
                    "service concurrency races)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to analyze with ALL rules; "
                         "omit to scan the repo with per-rule scopes + "
                         "baseline")
    ap.add_argument("--baseline", metavar="FILE",
                    help=f"ratchet file (repo mode default: "
                         f"{DEFAULT_BASELINE} at the repo root, if present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--rules", metavar="ID[,ID...]",
                    help="restrict to a comma-separated subset of rules")
    ap.add_argument("--paths", dest="path_filter", metavar="PREFIX[,...]",
                    help="repo mode only: restrict the scan to files whose "
                         "repo-relative path starts with one of these "
                         "prefixes (baseline entries outside them are "
                         "ignored, not stale)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="output format: human text (default) or one JSON "
                         "finding object per line")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every rule id and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(ALL_RULES):
            print(rule)
        return 0

    rules: Optional[set] = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(ALL_RULES)
        if unknown:
            print(f"error: unknown rule(s): {', '.join(sorted(unknown))} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    repo_mode = not args.paths
    prefixes: Optional[List[str]] = None
    if args.path_filter:
        if not repo_mode:
            print("error: --paths filters repo-mode scans; with explicit "
                  "paths just list what you want analyzed", file=sys.stderr)
            return 2
        prefixes = [p.strip() for p in args.path_filter.split(",")
                    if p.strip()]

    if repo_mode:
        files = _repo_files()
        if prefixes is not None:
            files = [(p, rel) for p, rel in files
                     if any(rel.startswith(pre) for pre in prefixes)]
            if not files:
                print(f"error: --paths {args.path_filter!r} matches no "
                      f"repo files", file=sys.stderr)
                return 2
    else:
        files, err = _explicit_files(args.paths)
        if err is not None:
            print(f"error: {err}", file=sys.stderr)
            return 2

    findings, lines_of, errors = _collect(files, repo_mode, rules)
    if errors:
        for err in errors:
            print(f"error: {err}", file=sys.stderr)
        return 2

    fp_of = {f: fingerprint(f, f.path, lines_of.get(f.path, []))
             for f in findings}

    baseline_path: Optional[Path] = None
    if args.baseline:
        baseline_path = Path(args.baseline)
    elif repo_mode:
        cand = _REPO_ROOT / DEFAULT_BASELINE
        if cand.is_file() or args.write_baseline:
            baseline_path = cand

    if args.write_baseline:
        if baseline_path is None:
            print("error: --write-baseline needs --baseline FILE in "
                  "explicit-path mode", file=sys.stderr)
            return 2
        entries = sorted(set(fp_of.values()))
        header = ("# Ratchet baseline for `python -m repro.analysis`.\n"
                  "# One fingerprint (path::rule::source-line) per entry —\n"
                  "# each is a pre-existing finding tolerated until fixed;\n"
                  "# stale entries FAIL the run so this file only shrinks.\n")
        baseline_path.write_text(
            header + "".join(e + "\n" for e in entries), encoding="utf-8")
        print(f"wrote {len(entries)} baseline entr"
              f"{'y' if len(entries) == 1 else 'ies'} to {baseline_path}")
        return 0

    baselined: List[Finding] = []
    stale: List[str] = []
    if baseline_path is not None and baseline_path.is_file():
        entries = load_baseline(str(baseline_path))
        if prefixes is not None:
            # entries for unscanned paths are out of sight: neither
            # applied nor reported stale under a narrowed scan
            entries = [e for e in entries
                       if any(e.split("::", 1)[0].startswith(pre)
                              for pre in prefixes)]
        findings, baselined, stale = apply_baseline(findings, entries, fp_of)
    elif args.baseline:
        print(f"error: baseline file {args.baseline!r} does not exist",
              file=sys.stderr)
        return 2

    if args.format == "json":
        for f in findings:
            print(_finding_json(f, fp_of[f], lines_of.get(f.path, [])))
        for entry in stale:
            print(json.dumps({"rule": "stale-baseline-entry", "path":
                              entry.split("::", 1)[0], "line": 0,
                              "source": "", "fingerprint": entry,
                              "message": "stale baseline entry (fix is "
                                         "in — delete the line)"}))
        return 1 if (findings or stale) else 0

    for f in findings:
        print(f.format())
    for entry in stale:
        print(f"stale baseline entry (fix is in — delete the line): {entry}")

    n_files = len(files)
    if findings or stale:
        print(f"analysis: {len(findings)} finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} across "
              f"{n_files} file(s)")
        return 1
    suffix = f" ({len(baselined)} baselined)" if baselined else ""
    print(f"analysis: clean — {n_files} file(s){suffix}")
    return 0
