"""``python -m repro.analysis`` — static invariant analyzer entry point.

Two modes:

  * **repo mode** (no paths): scan ``src/repro`` with each rule confined
    to its repo scope (kernel rules to ``core/backends/``, decision-layer
    float lint to ``engine.py``/``api.py``, …) and apply the committed
    ratchet baseline ``analysis-baseline.txt`` at the repo root.
  * **explicit mode** (paths given): apply *every* rule to exactly those
    files with no default baseline — this is what the fixture tests use
    to demonstrate each rule.

Exit codes: 0 clean, 1 findings (or stale baseline entries — the
ratchet only tightens), 2 broken invocation (missing file, syntax
error, unknown rule).  All findings print as ``path:line: [rule] msg``.
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from . import kernels, lint, typing_gate
from .findings import (Finding, apply_baseline, apply_pragmas, fingerprint,
                       load_baseline)

#: every rule the analyzer knows, with its repo-mode path scope
ALL_RULES = {**lint.RULES, **kernels.RULES, **typing_gate.RULES}

_REPO_ROOT = Path(__file__).resolve().parents[3]
_SRC_ROOT = Path(__file__).resolve().parents[1]        # src/repro
DEFAULT_BASELINE = "analysis-baseline.txt"


def _parse(path: Path) -> Tuple[Optional[ast.Module], List[str], str]:
    try:
        text = path.read_text(encoding="utf-8")
    except OSError as e:
        return None, [], f"cannot read {path}: {e}"
    try:
        return ast.parse(text, filename=str(path)), text.splitlines(), ""
    except SyntaxError as e:
        return None, [], f"{path}:{e.lineno}: syntax error: {e.msg}"


def _repo_files() -> List[Tuple[Path, str]]:
    out = []
    for p in sorted(_SRC_ROOT.rglob("*.py")):
        rel = p.relative_to(_REPO_ROOT).as_posix()
        if rel.startswith("src/repro/analysis/"):
            continue                  # the analyzer does not police itself
        out.append((p, rel))
    return out


def _collect(files: Sequence[Tuple[Path, str]], repo_mode: bool,
             rules: Optional[set],
             ) -> Tuple[List[Finding], Dict[str, List[str]], List[str]]:
    findings: List[Finding] = []
    lines_of: Dict[str, List[str]] = {}
    errors: List[str] = []
    trees: List[Tuple[str, ast.Module]] = []
    for path, display in files:
        tree, lines, err = _parse(path)
        if tree is None:
            errors.append(err)
            continue
        lines_of[display] = lines
        trees.append((display, tree))
        for f in lint.run(display, tree, lines) + \
                kernels.run(display, tree, lines):
            findings.append(f)
    findings.extend(typing_gate.run(trees))

    if repo_mode:
        findings = [f for f in findings
                    if f.rule not in ALL_RULES or ALL_RULES[f.rule](f.path)]
    if rules is not None:
        findings = [f for f in findings if f.rule in rules]
    findings = apply_pragmas(findings, lines_of)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, lines_of, errors


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant analyzer (kernel races/layout, "
                    "bit-exactness lint, backend protocol gate)")
    ap.add_argument("paths", nargs="*",
                    help="files to analyze with ALL rules; omit to scan "
                         "the repo with per-rule scopes + baseline")
    ap.add_argument("--baseline", metavar="FILE",
                    help=f"ratchet file (repo mode default: "
                         f"{DEFAULT_BASELINE} at the repo root, if present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--rules", metavar="ID[,ID...]",
                    help="restrict to a comma-separated subset of rules")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every rule id and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(ALL_RULES):
            print(rule)
        return 0

    rules: Optional[set] = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(ALL_RULES)
        if unknown:
            print(f"error: unknown rule(s): {', '.join(sorted(unknown))} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    repo_mode = not args.paths
    if repo_mode:
        files = _repo_files()
    else:
        files = []
        for raw in args.paths:
            p = Path(raw)
            if not p.is_file():
                print(f"error: no such file: {raw}", file=sys.stderr)
                return 2
            files.append((p, raw))

    findings, lines_of, errors = _collect(files, repo_mode, rules)
    if errors:
        for err in errors:
            print(f"error: {err}", file=sys.stderr)
        return 2

    fp_of = {f: fingerprint(f, f.path, lines_of.get(f.path, []))
             for f in findings}

    baseline_path: Optional[Path] = None
    if args.baseline:
        baseline_path = Path(args.baseline)
    elif repo_mode:
        cand = _REPO_ROOT / DEFAULT_BASELINE
        if cand.is_file() or args.write_baseline:
            baseline_path = cand

    if args.write_baseline:
        if baseline_path is None:
            print("error: --write-baseline needs --baseline FILE in "
                  "explicit-path mode", file=sys.stderr)
            return 2
        entries = sorted(set(fp_of.values()))
        header = ("# Ratchet baseline for `python -m repro.analysis`.\n"
                  "# One fingerprint (path::rule::source-line) per entry —\n"
                  "# each is a pre-existing finding tolerated until fixed;\n"
                  "# stale entries FAIL the run so this file only shrinks.\n")
        baseline_path.write_text(
            header + "".join(e + "\n" for e in entries), encoding="utf-8")
        print(f"wrote {len(entries)} baseline entr"
              f"{'y' if len(entries) == 1 else 'ies'} to {baseline_path}")
        return 0

    baselined: List[Finding] = []
    stale: List[str] = []
    if baseline_path is not None and baseline_path.is_file():
        entries = load_baseline(str(baseline_path))
        findings, baselined, stale = apply_baseline(findings, entries, fp_of)
    elif args.baseline:
        print(f"error: baseline file {args.baseline!r} does not exist",
              file=sys.stderr)
        return 2

    for f in findings:
        print(f.format())
    for entry in stale:
        print(f"stale baseline entry (fix is in — delete the line): {entry}")

    n_files = len(files)
    if findings or stale:
        print(f"analysis: {len(findings)} finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} across "
              f"{n_files} file(s)")
        return 1
    suffix = f" ({len(baselined)} baselined)" if baselined else ""
    print(f"analysis: clean — {n_files} file(s){suffix}")
    return 0
