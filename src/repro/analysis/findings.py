"""Finding records, inline suppression pragmas, and the ratchet baseline.

A :class:`Finding` is one analyzer hit: ``(rule, path, line, message)``.
Two suppression mechanisms exist, mirroring the two legitimate reasons a
finding may stay in the tree:

  * **pragma** — ``# analysis: allow[rule-id] <one-line justification>``
    on the finding's line (or the line directly above it) marks a site
    that is *correct by design* (e.g. the Pallas backend's documented
    one-blocking-transfer-per-wave ``device_get``).  The justification
    text is mandatory: an allow without a reason is itself a finding.
  * **baseline** — a committed ratchet file (one fingerprint per line)
    holding *pre-existing* findings that are tolerated but must be
    burned down.  A finding whose fingerprint is in the baseline passes;
    a baseline entry that no longer matches any finding FAILS the run
    ("stale entry") so the file shrinks in the same change that fixes
    the code — the ratchet only ever tightens.

Fingerprints are ``relpath::rule::<stripped source line>`` — line-number
free, so unrelated edits above a baselined site do not churn the file.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Sequence, Tuple

PRAGMA_RE = re.compile(
    r"#\s*analysis:\s*allow\[(?P<rule>[a-z0-9-]+)\]\s*(?P<reason>.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit at ``path:line`` produced by ``rule``."""

    rule: str
    path: str        # as given to the pass (absolute or repo-relative)
    line: int        # 1-indexed
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def pragma_on(lines: Sequence[str], line: int) -> Dict[str, str]:
    """Allow-pragmas covering source line ``line`` (1-indexed):
    ``{rule-id: justification}`` from the line itself and the line
    directly above it."""
    out: Dict[str, str] = {}
    for ln in (line - 1, line):              # line above, then the line
        if 1 <= ln <= len(lines):
            m = PRAGMA_RE.search(lines[ln - 1])
            if m:
                out[m.group("rule")] = m.group("reason").strip()
    return out


def apply_pragmas(findings: Iterable[Finding],
                  lines_of: Dict[str, Sequence[str]]) -> List[Finding]:
    """Drop findings suppressed by a justified allow-pragma; turn
    *unjustified* pragma suppressions into their own finding."""
    kept: List[Finding] = []
    for f in findings:
        lines = lines_of.get(f.path)
        pragmas = pragma_on(lines, f.line) if lines is not None else {}
        if f.rule in pragmas:
            if not pragmas[f.rule]:
                kept.append(Finding(
                    "allow-without-reason", f.path, f.line,
                    f"allow[{f.rule}] pragma carries no justification "
                    f"(suppressed: {f.message})"))
            continue
        kept.append(f)
    return kept


def fingerprint(f: Finding, relpath: str,
                lines: Sequence[str]) -> str:
    snippet = lines[f.line - 1].strip() if 1 <= f.line <= len(lines) else ""
    return f"{relpath}::{f.rule}::{snippet}"


def load_baseline(path: str) -> List[str]:
    """Baseline fingerprints, one per line; ``#`` comments and blank
    lines are ignored (justifications live in the comments)."""
    entries: List[str] = []
    with open(path, encoding="utf-8") as fh:
        for raw in fh:
            line = raw.strip()
            if line and not line.startswith("#"):
                entries.append(line)
    return entries


def apply_baseline(findings: Sequence[Finding],
                   entries: Sequence[str],
                   fp_of: Dict[Finding, str]
                   ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split ``findings`` against the baseline.

    Returns ``(new, baselined, stale)``: findings not covered by the
    baseline, findings it tolerates, and baseline entries matching
    nothing (each stale entry must be deleted — the ratchet tightens).
    Duplicate fingerprints (several findings on one line) share one
    entry.
    """
    remaining = set(entries)
    new: List[Finding] = []
    baselined: List[Finding] = []
    for f in findings:
        fp = fp_of[f]
        if fp in entries:
            baselined.append(f)
            remaining.discard(fp)
        else:
            new.append(f)
    return new, baselined, sorted(remaining)
