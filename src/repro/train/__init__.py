from .step import batch_shardings, make_serve_step, make_train_step
