"""Train/serve step factories with mesh-aware shardings.

``make_train_step`` returns a function (params, opt_state, batch) ->
(params, opt_state, metrics); ``make_serve_step`` returns
(params, cache, tokens, positions) -> (logits, cache).  Both are meant to
be ``jax.jit``-ed with the sharding trees from the same factories.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.params import ParamSpec, _is_spec, param_shardings
from repro.models.sharding import param_sharding, spec_for
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import OptState, opt_state_specs

Tree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    remat: bool = True, microbatch: int = 1):
    """One optimizer step.  ``microbatch > 1`` splits the global batch into
    sequential accumulation steps (memory knob for the perf loop)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss(p, b):
        return M.loss_fn(cfg, p, b, remat=remat)

    def step(params: Tree, opt: OptState, batch: Tree
             ) -> Tuple[Tree, OptState, Dict[str, jax.Array]]:
        if microbatch <= 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                l_acc, g_acc = carry
                li, gi = jax.value_and_grad(loss)(params, b)
                return (l_acc + li,
                        jax.tree.map(jnp.add, g_acc, gi)), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (l, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), mb)
            l = l / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        new_params, new_opt, info = adamw_update(opt_cfg, params, grads, opt)
        info["loss"] = l
        return new_params, new_opt, info

    return step


def make_serve_step(cfg: ModelConfig):
    def step(params: Tree, cache: Tree, tokens: jax.Array,
             positions: jax.Array):
        return M.decode_step(cfg, params, cache, tokens, positions)
    return step


# -------------------------------------------------------------- shardings
def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Tree:
    """NamedSharding tree matching configs.base.input_specs."""
    def ns(*logical, dims=None):
        return NamedSharding(mesh, spec_for(logical, dims))

    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out: Tree = {}
        if cfg.embed_inputs:
            out["embeds"] = ns("batch", "seq", "embed",
                               dims=(B, S, cfg.d_model))
        else:
            out["tokens"] = ns("batch", "seq", dims=(B, S))
            if cfg.vision_prefix:
                out["vision_embeds"] = ns("batch", "seq", "embed",
                                          dims=(B, S // 4, cfg.d_model))
        if shape.kind == "train":
            out["labels"] = ns("batch", "seq", dims=(B, S))
        return out
    return {
        "tokens": ns("batch", None, dims=(B, 1)),
        "positions": ns("batch", dims=(B,)),
    }


def opt_shardings(cfg: ModelConfig) -> OptState:
    specs = opt_state_specs(cfg)
    return jax.tree.map(lambda s: param_sharding(s.axes, s.shape), specs,
                        is_leaf=_is_spec)


def cache_shardings(cfg: ModelConfig, batch: int, max_seq: int) -> Tree:
    specs = M.cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: param_sharding(s.axes, s.shape), specs,
                        is_leaf=_is_spec)
