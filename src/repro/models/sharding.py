"""Logical-axis sharding: params and activations carry logical axis names;
a rules table maps them to physical mesh axes (MaxText-style).

Physical mesh axes: ``pod`` (inter-pod DCN), ``data`` (batch / FSDP),
``model`` (tensor parallel).  The default rules implement FSDP + TP:
weights are sharded over BOTH data and model axes, activations shard batch
over (pod, data) and attention heads / ff over model.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, Tuple[str, ...]]

# logical axis name -> physical mesh axis (or tuple of them, or None)
RuleTable = Dict[str, Axis]

# The paper-faithful baseline layout (§Perf records changes against this).
DEFAULT_RULES: RuleTable = {
    "batch": ("pod", "data"),       # data parallel over pods and data axis
    "seq": None,
    "embed": None,                  # activation d_model: replicated
    "heads": "model",               # attention heads: tensor parallel
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",                  # mlp hidden: tensor parallel
    "vocab": "model",               # logits vocab dim
    # parameter axes (FSDP: shard the non-TP dim over data)
    "p_vocab": "model",
    # embed/head tables: vocab is 'model'-sharded; the d_model dim stays
    # replicated — sharding it over 'data' makes GSPMD batch-gather the
    # (B,S,V) grad in the head backward (37 GiB/device, see DESIGN.md)
    "p_embed": None,
    "p_in": "data",                 # fsdp dim of weight matrices
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_head_dim": None,
    "p_ff": "model",
    "p_experts": "model",           # expert parallelism on the model axis
    "p_ssm_inner": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",           # mamba2 per-head decode state
    "p_state": None,
    "state": None,
    "layers": None,                 # stacked-scan leading axis
    "conv": None,
    "expert": "model",              # dispatched expert activation dim
    "cache_seq": "model",           # KV-cache sequence dim (flash-decoding
    #                                 style split-K over the model axis)
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: RuleTable


_ctx = threading.local()


def _get() -> ShardingCtx:
    if not hasattr(_ctx, "cur"):
        _ctx.cur = ShardingCtx(None, dict(DEFAULT_RULES))
    return _ctx.cur


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[RuleTable] = None):
    """Activate a mesh + rule table for model construction/lowering."""
    prev = getattr(_ctx, "cur", None)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _ctx.cur = ShardingCtx(mesh, merged)
    try:
        yield
    finally:
        if prev is None:
            del _ctx.cur
        else:
            _ctx.cur = prev


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def spec_for(logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated).

    Mesh axes that don't exist on the active mesh are dropped (so the same
    rules serve the single-pod (data, model) and multi-pod (pod, data,
    model) meshes).  When ``shape`` is given, axes whose sizes don't divide
    the dimension are dropped too (e.g. 8 KV heads on a 16-way model axis
    fall back to replication instead of failing to lower).
    """
    ctx = _get()
    avail = set(_mesh_axes(ctx.mesh)) if ctx.mesh is not None else set()
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)) \
        if ctx.mesh is not None else {}
    out = []
    used = set()
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        phys = ctx.rules.get(name, None)
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        keep = []
        quo = shape[i] if shape is not None else None
        for a in phys:
            if a not in avail or a in used:
                continue
            if quo is not None:
                if quo % sizes[a] != 0:
                    continue
                quo //= sizes[a]
            keep.append(a)
            used.add(a)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation to the logical spec (no-op without mesh)."""
    ctx = _get()
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec_for(logical, x.shape)))


def param_sharding(logical: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None
                   ) -> Optional[NamedSharding]:
    ctx = _get()
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, spec_for(logical, shape))
