"""Parameter specs: one source of truth for shapes, logical sharding axes
and initializers.  Materializes real arrays (training), ShapeDtypeStructs
(dry-run) or NamedShardings (pjit in/out specs) from the same tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .sharding import param_sharding

Tree = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | a_log | dt_bias
    dtype: Any = jnp.float32


def _attn_specs(cfg: ModelConfig, L: Optional[int]) -> Tree:
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pre = (L,) if L else ()
    lax = ("layers",) if L else ()
    s: Tree = {
        "wq": ParamSpec(pre + (D, H, dh), lax + ("p_in", "p_heads", None)),
        "wk": ParamSpec(pre + (D, K, dh), lax + ("p_in", "p_kv_heads", None)),
        "wv": ParamSpec(pre + (D, K, dh), lax + ("p_in", "p_kv_heads", None)),
        "wo": ParamSpec(pre + (H * dh, D), lax + ("p_ff", "p_in")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec(pre + (H, dh), lax + ("p_heads", None), "zeros")
        s["bk"] = ParamSpec(pre + (K, dh), lax + ("p_kv_heads", None), "zeros")
        s["bv"] = ParamSpec(pre + (K, dh), lax + ("p_kv_heads", None), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec(pre + (dh,), lax + (None,), "ones")
        s["k_norm"] = ParamSpec(pre + (dh,), lax + (None,), "ones")
    return s


def _mlp_specs(cfg: ModelConfig, L: Optional[int]) -> Tree:
    D, F = cfg.d_model, cfg.d_ff
    pre = (L,) if L else ()
    lax = ("layers",) if L else ()
    s: Tree = {
        "w_up": ParamSpec(pre + (D, F), lax + ("p_in", "p_ff")),
        "w_down": ParamSpec(pre + (F, D), lax + ("p_ff", "p_in")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        s["w_gate"] = ParamSpec(pre + (D, F), lax + ("p_in", "p_ff"))
    return s


def _moe_specs(cfg: ModelConfig, L: Optional[int]) -> Tree:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pre = (L,) if L else ()
    lax = ("layers",) if L else ()
    s: Tree = {
        "w_router": ParamSpec(pre + (D, E), lax + ("p_in", None)),
        "w_up": ParamSpec(pre + (E, D, F), lax + ("p_experts", "p_in", "p_ff")),
        "w_down": ParamSpec(pre + (E, F, D), lax + ("p_experts", "p_ff", "p_in")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        s["w_gate"] = ParamSpec(pre + (E, D, F),
                                lax + ("p_experts", "p_in", "p_ff"))
    return s


def _mamba1_specs(cfg: ModelConfig, L: int) -> Tree:
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    k = cfg.d_conv
    pre, lax = (L,), ("layers",)
    return {
        "w_in": ParamSpec(pre + (D, 2 * Di), lax + ("p_in", "p_ssm_inner")),
        "conv_w": ParamSpec(pre + (k, Di), lax + (None, "p_ssm_inner")),
        "conv_b": ParamSpec(pre + (Di,), lax + ("p_ssm_inner",), "zeros"),
        "w_x": ParamSpec(pre + (Di, R + 2 * N), lax + ("p_ssm_inner", None)),
        "w_dt": ParamSpec(pre + (R, Di), lax + (None, "p_ssm_inner")),
        "dt_bias": ParamSpec(pre + (Di,), lax + ("p_ssm_inner",), "dt_bias"),
        "A_log": ParamSpec(pre + (Di, N), lax + ("p_ssm_inner", None), "a_log"),
        "D_skip": ParamSpec(pre + (Di,), lax + ("p_ssm_inner",), "ones"),
        "w_out": ParamSpec(pre + (Di, D), lax + ("p_ssm_inner", "p_in")),
        "norm": ParamSpec(pre + (D,), lax + (None,), "ones"),
    }


def _mamba2_specs(cfg: ModelConfig, shape_pre: Tuple[int, ...]) -> Tree:
    D, Di, N = cfg.d_model, cfg.d_inner, cfg.d_state
    Hs, k = cfg.n_ssm_heads, cfg.d_conv
    pre = shape_pre
    lax = ("layers",) * len(shape_pre)
    dproj = 2 * Di + 2 * N + Hs
    return {
        "w_in": ParamSpec(pre + (D, dproj), lax + ("p_in", None)),
        "conv_w": ParamSpec(pre + (k, Di + 2 * N), lax + (None, None)),
        "conv_b": ParamSpec(pre + (Di + 2 * N,), lax + (None,), "zeros"),
        "dt_bias": ParamSpec(pre + (Hs,), lax + (None,), "dt_bias"),
        "A_log": ParamSpec(pre + (Hs,), lax + (None,), "a_log"),
        "D_skip": ParamSpec(pre + (Hs,), lax + (None,), "ones"),
        "out_norm": ParamSpec(pre + (Di,), lax + (None,), "ones"),
        "w_out": ParamSpec(pre + (Di, D), lax + ("p_ssm_inner", "p_in")),
        "norm": ParamSpec(pre + (D,), lax + (None,), "ones"),
    }


def param_specs(cfg: ModelConfig) -> Tree:
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    specs: Tree = {
        "embed": ParamSpec((V, D), ("p_vocab", "p_embed")),
        "final_norm": ParamSpec((D,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((V, D), ("p_vocab", "p_embed"))
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        blocks: Tree = {
            "attn": _attn_specs(cfg, L),
            "norm1": ParamSpec((L, D), ("layers", None), "ones"),
            "norm2": ParamSpec((L, D), ("layers", None), "ones"),
        }
        blocks["mlp" if cfg.family != "moe" else "moe"] = (
            _mlp_specs(cfg, L) if cfg.family != "moe" else _moe_specs(cfg, L))
        specs["blocks"] = blocks
    elif cfg.family == "ssm":
        specs["blocks"] = _mamba1_specs(cfg, L)
    elif cfg.family == "hybrid":
        n_groups = L // cfg.attn_every
        specs["blocks"] = _mamba2_specs(cfg, (n_groups, cfg.attn_every))
        specs["shared"] = {
            "attn": _attn_specs(cfg, None),
            "mlp": _mlp_specs(cfg, None),
            "norm1": ParamSpec((D,), (None,), "ones"),
            "norm2": ParamSpec((D,), (None,), "ones"),
        }
    else:
        raise ValueError(cfg.family)
    return specs


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "normal":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * scale).astype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "a_log":
        n = spec.shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, spec.shape).astype(spec.dtype)
    if spec.init == "dt_bias":
        val = float(np.log(np.expm1(0.01)))
        return jnp.full(spec.shape, val, spec.dtype)
    raise ValueError(spec.init)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef,
                              [_init_leaf(s, k) for s, k in zip(leaves, keys)])


def abstract_params(cfg: ModelConfig) -> Tree:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        param_specs(cfg), is_leaf=_is_spec)


def param_shardings(cfg: ModelConfig) -> Tree:
    """NamedSharding tree (requires an active use_sharding mesh)."""
    return jax.tree.map(lambda s: param_sharding(s.axes, s.shape),
                        param_specs(cfg), is_leaf=_is_spec)


def param_bytes(cfg: ModelConfig) -> int:
    specs = jax.tree.leaves(param_specs(cfg), is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in specs)
