"""Unified model: forward / loss / decode for all six architecture families.

Layers are stacked and scanned (``lax.scan``) so the HLO stays one block
body regardless of depth — essential for 512-device dry-run compiles.
The zamba2 hybrid scans groups of Mamba-2 layers with the *shared*
attention block applied between groups (weight-shared, per-group KV cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from . import layers as Lyr
from .layers import attention, mamba1, mamba2, mlp, moe, rms_norm
from .params import ParamSpec, _is_spec
from .sharding import shard

Tree = Dict[str, Any]


def _cast(tree: Tree, dtype) -> Tree:
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, tree)


_EMBED_LOOKUP_CACHE: Dict[Any, Any] = {}


def _embed_lookup_for(V: int, D: int, dtype) -> Any:
    """custom-vjp embedding lookup specialized to the table signature.

    Backward: scatter-add per data-shard into a replicated fp32 table,
    then constrain back to the sharded layout — one table-sized reduce
    instead of the batch-replicated one-hot GSPMD would otherwise build
    (37 GiB/device at qwen2-0.5b train_4k).
    """
    key = (V, D, jnp.dtype(dtype).name)
    if key in _EMBED_LOOKUP_CACHE:
        return _EMBED_LOOKUP_CACHE[key]

    @jax.custom_vjp
    def lookup(table, tokens):
        return jnp.take(table, tokens, axis=0)

    def fwd(table, tokens):
        return jnp.take(table, tokens, axis=0), tokens

    def bwd(tokens, g):
        flat_tok = tokens.reshape(-1)
        flat_g = g.reshape(-1, D).astype(jnp.float32)
        dtable = jnp.zeros((V, D), jnp.float32).at[flat_tok].add(flat_g)
        dtable = shard(dtable, "vocab", None).astype(dtype)
        return dtable, None

    lookup.defvjp(fwd, bwd)
    _EMBED_LOOKUP_CACHE[key] = lookup
    return lookup


def _embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    V, D = table.shape
    return _embed_lookup_for(V, D, table.dtype)(table, tokens)


def _embed_tokens(cfg: ModelConfig, params: Tree, batch: Tree) -> jax.Array:
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.embed_inputs:
        x = batch["embeds"].astype(f)
    else:
        # gather the FSDP d_model shard of the table at use-site (weights
        # are cheap to gather; gathering activations replicates the batch)
        table = shard(params["embed"], "vocab", None)
        x = _embed_lookup(table, batch["tokens"]).astype(f)
        if cfg.vision_prefix and "vision_embeds" in batch:
            x = jax.lax.dynamic_update_slice(
                x, batch["vision_embeds"].astype(f), (0, 0, 0))
    return shard(x, "batch", "seq", "embed")


def _dense_block(cfg: ModelConfig, p: Tree, x: jax.Array,
                 positions: jax.Array) -> jax.Array:
    h, _ = attention(cfg, p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                     positions)
    x = x + h
    xn = rms_norm(x, p["norm2"], cfg.norm_eps)
    ff = moe(cfg, p["moe"], xn) if "moe" in p else mlp(cfg, p["mlp"], xn)
    # pin the scan-carry layout: without this GSPMD lays the loop state out
    # batch-replicated / d_model-sharded and drags 37 GiB gathers behind it
    return shard(x + ff, "batch", "seq", "embed")


def _ssm_block(cfg: ModelConfig, p: Tree, x: jax.Array) -> jax.Array:
    h, _ = mamba1(cfg, p, rms_norm(x, p["norm"], cfg.norm_eps))
    return shard(x + h, "batch", "seq", "embed")


def _mamba2_block(cfg: ModelConfig, p: Tree, x: jax.Array) -> jax.Array:
    h, _ = mamba2(cfg, p, rms_norm(x, p["norm"], cfg.norm_eps))
    return shard(x + h, "batch", "seq", "embed")


def forward(cfg: ModelConfig, params: Tree, batch: Tree,
            remat: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V) in fp32."""
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params = _cast(params, f)
    x = _embed_tokens(cfg, params, batch)
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(carry, p):
            return _dense_block(cfg, p, carry, positions), None
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "ssm":
        def body(carry, p):
            return _ssm_block(cfg, p, carry), None
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def inner(carry, p):
            return _mamba2_block(cfg, p, carry), None
        if remat:
            inner = jax.checkpoint(inner)

        def group(carry, pg):
            h, _ = jax.lax.scan(inner, carry, pg)
            a, _ = attention(cfg, shared["attn"],
                             rms_norm(h, shared["norm1"], cfg.norm_eps),
                             positions)
            h = h + a
            h = h + mlp(cfg, shared["mlp"],
                        rms_norm(h, shared["norm2"], cfg.norm_eps))
            return shard(h, "batch", "seq", "embed"), None
        if remat:
            group = jax.checkpoint(group)
        x, _ = jax.lax.scan(group, x, params["blocks"])
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = shard(params.get("lm_head", params["embed"]), "vocab", None)
    logits = jnp.einsum("bsd,vd->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params: Tree, batch: Tree,
            remat: bool = True) -> jax.Array:
    logits = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (a gather across vocab shards would force GSPMD to replicate
    # the full (B, S, V) logits — 37 GiB/device at qwen2-0.5b train_4k).
    onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - gold)


# ------------------------------------------------------------------ decode
def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Tree:
    """ParamSpec tree for the decode state (KV cache / SSM state)."""
    B, S = batch, max_seq
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family in ("dense", "vlm", "moe"):
        ax = ("layers", "batch", "cache_seq", None, None)
        return {
            "k": ParamSpec((L, B, S, K, dh), ax, "zeros", f),
            "v": ParamSpec((L, B, S, K, dh), ax, "zeros", f),
        }
    if cfg.family == "ssm":
        Di, N, k = cfg.d_inner, cfg.d_state, cfg.d_conv
        return {
            "h": ParamSpec((L, B, Di, N),
                           ("layers", "batch", "ssm_inner", None),
                           "zeros", jnp.float32),
            "conv": ParamSpec((L, B, k - 1, Di),
                              ("layers", "batch", None, "ssm_inner"),
                              "zeros", f),
        }
    if cfg.family == "hybrid":
        G = L // cfg.attn_every
        per = cfg.attn_every
        Di, N, k = cfg.d_inner, cfg.d_state, cfg.d_conv
        Hs, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
        return {
            "ssm_h": ParamSpec((G, per, B, Hs, hd, N),
                               ("layers", "layers", "batch", "ssm_heads",
                                None, None), "zeros", jnp.float32),
            "ssm_conv": ParamSpec((G, per, B, k - 1, Di + 2 * N),
                                  ("layers", "layers", "batch", None,
                                   "ssm_inner"), "zeros", f),
            "k": ParamSpec((G, B, S, K, dh),
                           ("layers", "batch", "cache_seq", None, None),
                           "zeros", f),
            "v": ParamSpec((G, B, S, K, dh),
                           ("layers", "batch", "cache_seq", None, None),
                           "zeros", f),
        }
    raise ValueError(f"{cfg.family} has no decode state")


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq), is_leaf=_is_spec)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tree:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq), is_leaf=_is_spec)


def decode_step(cfg: ModelConfig, params: Tree, cache: Tree,
                tokens: jax.Array, positions: jax.Array
                ) -> Tuple[jax.Array, Tree]:
    """One serve step: tokens (B, 1), positions (B,) -> logits (B, 1, V)."""
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params = _cast(params, f)
    x = _embed_tokens(cfg, params, {"tokens": tokens})
    pos2d = positions[:, None]

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, xs):
            p, ck, cv = xs
            xn = rms_norm(carry, p["norm1"], cfg.norm_eps)
            h, nc = attention(cfg, p["attn"], xn, pos2d,
                              cache={"k": ck, "v": cv}, cache_pos=positions)
            h = carry + h
            xn = rms_norm(h, p["norm2"], cfg.norm_eps)
            ff = moe(cfg, p["moe"], xn) if "moe" in p else mlp(cfg, p["mlp"], xn)
            return h + ff, (nc["k"], nc["v"])
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    elif cfg.family == "ssm":
        def body(carry, xs):
            p, h0, conv0 = xs
            xn = rms_norm(carry, p["norm"], cfg.norm_eps)
            y, st = mamba1(cfg, p, xn, state={"h": h0, "conv": conv0})
            return carry + y, (st["h"], st["conv"])
        x, (nh, nconv) = jax.lax.scan(
            body, x, (params["blocks"], cache["h"], cache["conv"]))
        new_cache = {"h": nh, "conv": nconv}
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def inner(carry, xs):
            p, h0, conv0 = xs
            xn = rms_norm(carry, p["norm"], cfg.norm_eps)
            y, st = mamba2(cfg, p, xn, state={"h": h0, "conv": conv0})
            return carry + y, (st["h"], st["conv"])

        def group(carry, xs):
            pg, h0g, conv0g, ck, cv = xs
            h, (nh, nconv) = jax.lax.scan(inner, carry, (pg, h0g, conv0g))
            xn = rms_norm(h, shared["norm1"], cfg.norm_eps)
            a, nc = attention(cfg, shared["attn"], xn, pos2d,
                              cache={"k": ck, "v": cv}, cache_pos=positions)
            h = h + a
            h = h + mlp(cfg, shared["mlp"],
                        rms_norm(h, shared["norm2"], cfg.norm_eps))
            return h, (nh, nconv, nc["k"], nc["v"])
        x, (nh, nconv, nk, nv) = jax.lax.scan(
            group, x, (params["blocks"], cache["ssm_h"], cache["ssm_conv"],
                       cache["k"], cache["v"]))
        new_cache = {"ssm_h": nh, "ssm_conv": nconv, "k": nk, "v": nv}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = shard(params.get("lm_head", params["embed"]), "vocab", None)
    logits = jnp.einsum("bsd,vd->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab"), new_cache
