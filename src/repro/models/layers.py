"""Model layers, pure-JAX reference path (Pallas kernels plug in via
``repro.kernels`` where perf-critical; the reference path is what the
CPU dry-run lowers).

All functions are functional: ``params`` are plain dicts of arrays.
Activation sharding constraints use logical axis names (see sharding.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .sharding import shard

Params = Dict[str, jax.Array]

# Query-chunk size above which attention switches to the memory-bounded
# online-softmax path (pure-JAX flash-style; the Pallas kernel is the TPU
# realization of the same schedule).
ATTN_CHUNK_THRESHOLD = 8192
ATTN_CHUNK = 2048
# MoE dispatch group size + capacity factor (GShard-style).
MOE_GROUP = 256
MOE_CAPACITY_FACTOR = 1.25


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


# ----------------------------------------------------------------- rotary
def _rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rot(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (even, odd) of the last dim; cos/sin (..., d/2)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def apply_rope(cfg: ModelConfig, q: jax.Array, k: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """q (B,S,H,dh), k (B,S,K,dh), positions (B,S)."""
    dh = cfg.head_dim
    if cfg.rope == "none":
        return q, k
    if cfg.rope == "standard":
        cos, sin = _rope_angles(positions, dh, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        return _apply_rot(q, cos, sin), _apply_rot(k, cos, sin)
    if cfg.rope == "partial":
        # chatglm-style 2d RoPE: rotary on the first half of head_dim.
        rd = dh // 2
        cos, sin = _rope_angles(positions, rd, cfg.rope_theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        q = jnp.concatenate([_apply_rot(q[..., :rd], cos, sin), q[..., rd:]], -1)
        k = jnp.concatenate([_apply_rot(k[..., :rd], cos, sin), k[..., rd:]], -1)
        return q, k
    if cfg.rope == "mrope":
        # qwen2-vl M-RoPE: head_dim split into (t, h, w) sections with
        # separate position streams (stub: derived from the 1-d position).
        sec = dh // 2 // 4                      # quarters: 2t, 1h, 1w
        pos_t = positions
        pos_h = positions // 64
        pos_w = positions % 64
        cos_t, sin_t = _rope_angles(pos_t, dh, cfg.rope_theta)
        cos_h, sin_h = _rope_angles(pos_h, dh, cfg.rope_theta)
        cos_w, sin_w = _rope_angles(pos_w, dh, cfg.rope_theta)
        idx = jnp.arange(dh // 2)
        sel_h = (idx >= 2 * sec) & (idx < 3 * sec)
        sel_w = idx >= 3 * sec
        cos = jnp.where(sel_h, cos_h, jnp.where(sel_w, cos_w, cos_t))
        sin = jnp.where(sel_h, sin_h, jnp.where(sel_w, sin_w, sin_t))
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        return _apply_rot(q, cos, sin), _apply_rot(k, cos, sin)
    raise ValueError(f"unknown rope variant {cfg.rope!r}")


# -------------------------------------------------------------- attention
def _qk_norm(q, k, p, eps):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    return q, k


def _sdpa_full(q, k, v, causal: bool, q_offset) -> jax.Array:
    """q (B,Sq,K,G,dh), k/v (B,Sk,K,dh) -> (B,Sq,K,G,dh)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = q_offset + jnp.arange(sq)
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)


def _sdpa_chunked(q, k, v, causal: bool) -> jax.Array:
    """Online-softmax over query chunks: O(S*C) score memory instead of
    O(S^2).  Pure-JAX expression of the FlashAttention schedule."""
    B, S, K, G, dh = q.shape
    C = ATTN_CHUNK
    n = S // C
    scale = 1.0 / math.sqrt(dh)
    qc = q.reshape(B, n, C, K, G, dh)

    def one_chunk(i, qi):
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32) * scale,
                            k.astype(jnp.float32))
        if causal:
            qpos = i * C + jnp.arange(C)
            mask = qpos[:, None] >= jnp.arange(S)[None, :]
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n), jnp.moveaxis(qc, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, K, G, dh)


def attention(cfg: ModelConfig, p: Params, x: jax.Array,
              positions: jax.Array,
              cache: Optional[Params] = None,
              cache_pos: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Params]]:
    """GQA attention.  Train/prefill: cache is None.  Decode: x is (B,1,D)
    and (cache, cache_pos) carry the KV cache and current lengths."""
    B, S, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    q, k = _qk_norm(q, k, p, cfg.norm_eps)
    q, k = apply_rope(cfg, q, k, positions)
    qg = q.reshape(B, S, K, G, dh)

    new_cache = None
    if cache is not None:
        # single-token decode against the cache (uniform positions across
        # the batch — the serving engine pads to a common step index)
        idx = cache_pos[0]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv}
        scale = 1.0 / math.sqrt(dh)
        scores = jnp.einsum("bqkgh,bskh->bkgqs",
                            qg.astype(jnp.float32) * scale,
                            ck.astype(jnp.float32))
        Sk = ck.shape[1]
        mask = jnp.arange(Sk)[None, :] <= cache_pos[:, None]   # (B, Sk)
        scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cv.dtype), cv)
    elif S > ATTN_CHUNK_THRESHOLD and S % ATTN_CHUNK == 0:
        out = _sdpa_chunked(qg, k, v, cfg.causal)
    else:
        out = _sdpa_full(qg, k, v, cfg.causal, 0)

    out = out.reshape(B, S, H * dh)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return shard(out, "batch", "seq", "embed"), new_cache


# -------------------------------------------------------------------- mlp
def mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * \
            jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    h = shard(h, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return shard(out, "batch", "seq", "embed")


# -------------------------------------------------------------------- moe
def moe(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """GShard-style top-k MoE with grouped one-hot dispatch + capacity.

    Tokens are processed in groups of MOE_GROUP; each group dispatches to
    per-expert capacity ``C = top_k * G / E * capacity_factor`` (overflow
    tokens drop to the residual path, standard for TPU MoE).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = min(MOE_GROUP, B * S)
    T = B * S
    n_groups = T // G
    C = max(1, int(k * G / E * MOE_CAPACITY_FACTOR))

    xt = x.reshape(n_groups, G, D)
    logits = jnp.einsum("ngd,de->nge", xt, p["w_router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (n, G, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, slot) inside its expert's capacity buffer:
    # exclusive cumcount of earlier picks of the same expert in the group
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (n,G,k,E)
    flat = onehot.reshape(n_groups, G * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, G, k, E)
    pos_sel = jnp.sum(pos * onehot, axis=-1)                 # (n,G,k)
    keep = pos_sel < C
    cap_oh = jax.nn.one_hot(pos_sel.astype(jnp.int32), C,
                            dtype=jnp.float32) * keep[..., None]
    disp_mask = jnp.einsum("ngke,ngkc->ngec", onehot, cap_oh)
    comb_mask = jnp.einsum("ngke,ngkc->ngec",
                           onehot * gate_vals[..., None], cap_oh)

    # keep the token-group dim batch-sharded: replicating it here gathers
    # every device's dispatched activations (17.5 GiB/step at olmoe
    # train_4k — §Perf iteration 1)
    xe = jnp.einsum("ngd,ngec->necd", xt, disp_mask.astype(x.dtype))
    xe = shard(xe, "batch", "expert", None, None)   # (n, E, C, D)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("necd,edf->necf", xe, p["w_gate"])) * \
            jnp.einsum("necd,edf->necf", xe, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("necd,edf->necf", xe, p["w_up"]))
    h = shard(h, "batch", "expert", None, "ff")
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"])
    out = jnp.einsum("necd,ngec->ngd", ye, comb_mask.astype(x.dtype))
    return shard(out.reshape(B, S, D), "batch", "seq", "embed")


# ------------------------------------------------------------------ mamba
def _ssm_chunk_scan(deltaA, deltaBx):
    """Sequential scan over chunks, parallel inside via cumulative products.

    deltaA, deltaBx: (B, n_chunks, C, Di, N) viewed per chunk.
    h_t = deltaA_t * h_{t-1} + deltaBx_t.
    """
    # intra-chunk: prefix products P_t = prod_{u<=t} deltaA_u
    logA = jnp.log(jnp.maximum(deltaA, 1e-20))
    cumA = jnp.exp(jnp.cumsum(logA, axis=2))                 # (B,nc,C,Di,N)
    # contribution of in-chunk inputs: sum_u (prod_{u<t<=T} A) * bx_u
    #   y_t = cumA_t * (h_in + sum_{u<=t} bx_u / cumA_u)
    inv = deltaBx / jnp.maximum(cumA, 1e-20)
    acc = jnp.cumsum(inv, axis=2)

    def step(h, xs):
        cumA_c, acc_c = xs                                   # (B,C,Di,N)
        h_states = cumA_c * (h[:, None] + acc_c)
        h_next = h_states[:, -1]
        return h_next, h_states

    B, nc, C, Di, N = deltaA.shape
    h0 = jnp.zeros((B, Di, N), deltaA.dtype)
    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(cumA, 1, 0), jnp.moveaxis(acc, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)                            # (B,nc,C,Di,N)


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array]
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv1d.  x (B,S,Ch), w (k,Ch)."""
    B, S, Ch = x.shape
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, k - 1, Ch), x.dtype)
        new_state = None
    else:
        pad = state
        new_state = jnp.concatenate([state, x], axis=1)[:, -(k - 1):]
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + S] * w[i] for i in range(k))
    return out, new_state


def mamba1(cfg: ModelConfig, p: Params, x: jax.Array,
           state: Optional[Params] = None,
           ) -> Tuple[jax.Array, Optional[Params]]:
    """Mamba-1 selective SSM block (falcon-mamba).  Chunked scan.

    Decode: ``state = {"h": (B,Di,N), "conv": (B,k-1,Di)}``.
    """
    B, S, D = x.shape
    Di, N, R = cfg.d_inner, cfg.d_state, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])             # (B,S,2Di)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", "seq", "ssm_inner")
    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
    xs = jax.nn.silu(xs + p["conv_b"])

    bcdt = jnp.einsum("bse,er->bsr", xs, p["w_x"])           # (B,S,R+2N)
    dt_low, Bss, Css = jnp.split(bcdt, [R, R + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_low, p["w_dt"])
                         + p["dt_bias"])                     # (B,S,Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (Di,N)

    deltaA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (B,S,Di,N)
    dBx = (dt * xs).astype(jnp.float32)[..., None] * \
        Bss.astype(jnp.float32)[:, :, None, :]               # (B,S,Di,N)

    if state is not None:
        h = deltaA[:, 0] * state["h"] + dBx[:, 0]            # (B,Di,N)
        y = jnp.einsum("ben,bn->be", h, Css[:, 0].astype(jnp.float32))
        y = y[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        C_chunk = min(256, S)
        nc = S // C_chunk
        hs = _ssm_chunk_scan(
            deltaA.reshape(B, nc, C_chunk, Di, N),
            dBx.reshape(B, nc, C_chunk, Di, N))
        hs = hs.reshape(B, S, Di, N)
        y = jnp.einsum("bsen,bsn->bse", hs, Css.astype(jnp.float32))
        new_state = None

    y = y.astype(x.dtype) + xs * p["D_skip"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return shard(out, "batch", "seq", "embed"), new_state


def mamba2(cfg: ModelConfig, p: Params, x: jax.Array,
           state: Optional[Params] = None,
           ) -> Tuple[jax.Array, Optional[Params]]:
    """Mamba-2 (SSD) block with scalar-per-head decay (zamba2 backbone).

    Chunked SSD: intra-chunk attention-like matmuls + inter-chunk state
    recurrence.  Decode: ``state = {"h": (B,Hs,dh,N), "conv": ...}``.
    """
    B, S, D = x.shape
    Di, N = cfg.d_inner, cfg.d_state
    Hs, dh = cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xs, Bss, Css, dt_raw = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bss, Css], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out + p["conv_b"])
    xs, Bss, Css = jnp.split(conv_out, [Di, Di + N], axis=-1)
    xs = shard(xs, "batch", "seq", "ssm_inner")

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (B,S,Hs)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (Hs,)
    dA = dt.astype(jnp.float32) * A                          # (B,S,Hs) log-decay
    xh = xs.reshape(B, S, Hs, dh)

    if state is not None:
        decay = jnp.exp(dA[:, 0])                            # (B,Hs)
        h = state["h"] * decay[..., None, None] + \
            jnp.einsum("bhe,bn->bhen", (dt[:, 0, :, None] * xh[:, 0]),
                       Bss[:, 0])
        y = jnp.einsum("bhen,bn->bhe", h, Css[:, 0])
        y = y.reshape(B, 1, Di)
        new_state = {"h": h, "conv": new_conv}
    else:
        C_chunk = min(256, S)
        nc = S // C_chunk
        dAc = dA.reshape(B, nc, C_chunk, Hs)
        cum = jnp.cumsum(dAc, axis=2)                        # (B,nc,C,Hs)
        xc = xh.reshape(B, nc, C_chunk, Hs, dh)
        dtc = dt.reshape(B, nc, C_chunk, Hs)
        Bc = Bss.reshape(B, nc, C_chunk, N).astype(jnp.float32)
        Cc = Css.reshape(B, nc, C_chunk, N).astype(jnp.float32)
        # intra-chunk: L[t,u] = exp(cum_t - cum_u) for t >= u
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,C,C,Hs)
        tri = jnp.tril(jnp.ones((C_chunk, C_chunk), bool))
        L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bntk,bnuk->bntu", Cc, Bc)       # (B,nc,C,C)
        y_intra = jnp.einsum("bntu,bntuh,bnuhe->bnthe",
                             scores, L, (dtc[..., None] * xc).astype(jnp.float32))
        # inter-chunk: carry state across chunks
        seg_end = cum[:, :, -1]                              # (B,nc,Hs)
        chunk_state = jnp.einsum("bnuh,bnuhe,bnuk->bnhek",
                                 jnp.exp(seg_end[:, :, None] - cum),
                                 (dtc[..., None] * xc).astype(jnp.float32), Bc)

        def step(h, xs_):
            st, end = xs_
            out = h
            h = h * jnp.exp(end)[..., None, None] + st
            return h, out

        h0 = jnp.zeros((B, Hs, dh, N), jnp.float32)
        _, h_in = jax.lax.scan(
            step, h0, (jnp.moveaxis(chunk_state, 1, 0),
                       jnp.moveaxis(seg_end, 1, 0)))
        h_in = jnp.moveaxis(h_in, 0, 1)                      # (B,nc,Hs,dh,N)
        y_inter = jnp.einsum("bntk,bnth,bnhek->bnthe",
                             Cc, jnp.exp(cum), h_in)
        y = (y_intra + y_inter).reshape(B, S, Hs, dh)
        y = y.reshape(B, S, Di)
        new_state = None

    y = y.astype(x.dtype) + xs * p["D_skip"].repeat(dh)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return shard(out, "batch", "seq", "embed"), new_state
