"""SPG construction from model configs and serving-query sets.

``model_stage_graph`` — the training/serving pipeline of one model as a
chain SPG (embed -> stage units -> head).

``serving_query_graph`` — the automotive-DSMS analogue: several registered
queries (applications) consume shared backbone outputs; sharing creates
high-out-degree hub nodes at depth > 1, exactly the SPG family (Section
3.2) that breaks HSV_CC ordering and motivates HVLB_CC (B).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.graph import SPG

from .cost_model import stage_graph_costs


def model_stage_graph(cfg: ModelConfig, shape: ShapeConfig,
                      n_stage_units: int = 16) -> SPG:
    """Chain SPG: embed -> unit_1 .. unit_k -> head.

    Node weights are FLOPs (so ``comp = w / mu`` with mu in FLOP/s yields
    seconds); edge volumes are boundary activation bytes.
    """
    units, act_bytes = stage_graph_costs(cfg, shape, n_stage_units)
    from .cost_model import layer_costs
    c = layer_costs(cfg, shape)
    weights = [c["embed"]] + units + [c["head"]]
    n = len(weights)
    edges = [(i, i + 1) for i in range(n - 1)]
    g = SPG(n=n, edges=edges, weights=np.asarray(weights),
            name=f"{cfg.name}-{shape.name}")
    for e in edges:
        g.tpl[e] = float(act_bytes)
    return g


def pipeline_graph(cfg: ModelConfig, shape: ShapeConfig,
                   n_microbatches: int = 8,
                   n_stage_units: int = 16) -> SPG:
    """M parallel microbatch chains (each 1/M of the tokens).

    List-scheduling this DAG is pipeline-schedule synthesis: processor
    contention serializes stages on a slice while independent microbatches
    overlap — the GPipe bubble appears as schedule holes (which
    HVLB_CC_IC can fill with optional work, Section 4.4).
    """
    units, act_bytes = stage_graph_costs(cfg, shape, n_stage_units)
    from .cost_model import layer_costs
    c = layer_costs(cfg, shape)
    chain = [c["embed"]] + units + [c["head"]]
    chain = [w / n_microbatches for w in chain]
    act = act_bytes / n_microbatches
    k = len(chain)
    weights: List[float] = []
    edges: List[Tuple[int, int]] = []
    for m in range(n_microbatches):
        base = m * k
        weights.extend(chain)
        edges.extend((base + i, base + i + 1) for i in range(k - 1))
    g = SPG(n=len(weights), edges=edges, weights=np.asarray(weights),
            name=f"{cfg.name}-pipe{n_microbatches}x{k}")
    for e in edges:
        g.tpl[e] = float(act)
    return g


def serving_query_graph(cfg: ModelConfig, shape: ShapeConfig,
                        n_queries: int = 3,
                        n_stage_units: int = 8) -> SPG:
    """Backbone + per-query operator subgraphs (the DSMS workload).

    Each registered query taps the backbone output (and optionally an
    intermediate stage), runs 2-3 post-processing operators (filter /
    map / join analogues as FLOP-weighted tasks) and ends in an
    application sink.  The backbone output node acquires out-degree
    ``n_queries`` > its predecessors' out-degree — the stream-processing
    shape of the paper.

    The returned SPG carries ``query_ops``: query index -> the node ids
    of its ``(op1, op2, sink)`` operators.  Consumers (``serve.DSMSEngine``)
    must use this mapping instead of recomputing node positions from the
    graph size, so graph-shape changes cannot silently misattribute
    schedule holes.
    """
    base = model_stage_graph(cfg, shape, n_stage_units)
    weights: List[float] = list(base.weights)
    edges: List[Tuple[int, int]] = list(base.edges)
    tpl: Dict[Tuple[int, int], float] = dict(base.tpl)
    act = tpl[base.edges[0]]
    hub = base.n - 1                      # head output feeds every query
    query_ops: Dict[int, Tuple[int, int, int]] = {}
    rng = np.random.default_rng(0)
    for q in range(n_queries):
        # operator 1 (filter/map) <- hub
        op1 = len(weights)
        weights.append(float(weights[hub]) * 0.05 * (1 + q % 3))
        edges.append((hub, op1))
        tpl[(hub, op1)] = act * 0.1
        # operator 2 (join with an intermediate tap every other query)
        op2 = len(weights)
        weights.append(float(weights[hub]) * 0.02)
        edges.append((op1, op2))
        tpl[(op1, op2)] = act * 0.05
        if q % 2 == 1:
            tap = 1 + (q % (base.n - 2))
            edges.append((tap, op2))
            tpl[(tap, op2)] = act * 0.05
        # sink application
        sink = len(weights)
        weights.append(float(weights[hub]) * 0.01)
        edges.append((op2, sink))
        tpl[(op2, sink)] = act * 0.01
        query_ops[q] = (op1, op2, sink)
    g = SPG(n=len(weights), edges=edges, weights=np.asarray(weights),
            name=f"{cfg.name}-dsms-{n_queries}q")
    g.tpl.update(tpl)
    g.query_ops = query_ops
    return g
