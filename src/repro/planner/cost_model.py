"""Roofline cost model: per-stage FLOPs / bytes for an architecture cell.

Task weights for the HVLB_CC placement are stage *compute volumes* (FLOPs);
edge volumes are activation bytes crossing stage boundaries; processor
execution rates are slice FLOP/s — the paper's ``comp = w / mu`` (Eq. 1)
becomes ``time = FLOPs / (chips * peak * mfu)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e-like constants (per chip / per link)."""
    peak_flops: float = 197e12          # bf16
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    ici_links: int = 4
    dcn_bw: float = 6.25e9              # bytes/s per host cross-pod
    mfu: float = 0.5                    # assumed sustained fraction


def _attn_flops(cfg: ModelConfig, tokens: int, kv_len: int) -> float:
    H, dh = cfg.n_heads, cfg.head_dim
    K = cfg.n_kv_heads
    D = cfg.d_model
    proj = 2 * tokens * D * (H * dh) * 2 + 2 * tokens * D * (K * dh) * 2
    scores = 2 * tokens * kv_len * H * dh * 2        # qk + av
    return proj + scores


def _mlp_flops(cfg: ModelConfig, tokens: int) -> float:
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    return 2 * tokens * cfg.d_model * cfg.d_ff * mult


def _moe_flops(cfg: ModelConfig, tokens: int) -> float:
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    expert = 2 * tokens * cfg.top_k * cfg.d_model * cfg.d_ff * mult
    router = 2 * tokens * cfg.d_model * cfg.n_experts
    return expert + router


def _mamba1_flops(cfg: ModelConfig, tokens: int) -> float:
    D, Di, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    proj = 2 * tokens * D * 2 * Di + 2 * tokens * Di * D
    lowrank = 2 * tokens * Di * (R + 2 * N) + 2 * tokens * R * Di
    scan = tokens * Di * N * 6                      # recurrence+readout
    return proj + lowrank + scan


def _mamba2_flops(cfg: ModelConfig, tokens: int) -> float:
    D, Di, N = cfg.d_model, cfg.d_inner, cfg.d_state
    Hs = cfg.n_ssm_heads
    proj = 2 * tokens * D * (2 * Di + 2 * N + Hs) + 2 * tokens * Di * D
    chunk = 256
    ssd = (2 * tokens * chunk * N            # C B^T scores
           + 2 * tokens * chunk * cfg.ssm_head_dim * Hs / max(Hs, 1)
           + 6 * tokens * Di * N / chunk)
    return proj + ssd * Hs


def layer_costs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """FLOPs per single layer/block and activation bytes per boundary."""
    if shape.kind == "decode":
        tokens = shape.global_batch                  # one token per seq
        kv_len = shape.seq_len
    else:
        tokens = shape.global_batch * shape.seq_len
        kv_len = shape.seq_len
    act_bytes = tokens * cfg.d_model * 2             # bf16 boundary tensor
    out: Dict[str, float] = {"act_bytes": float(act_bytes)}
    if cfg.family in ("dense", "vlm", "audio"):
        out["block"] = _attn_flops(cfg, tokens, kv_len) + _mlp_flops(cfg, tokens)
    elif cfg.family == "moe":
        out["block"] = _attn_flops(cfg, tokens, kv_len) + _moe_flops(cfg, tokens)
    elif cfg.family == "ssm":
        out["block"] = _mamba1_flops(cfg, tokens)
    elif cfg.family == "hybrid":
        out["block"] = _mamba2_flops(cfg, tokens)
        out["shared_attn"] = (_attn_flops(cfg, tokens, kv_len) +
                              _mlp_flops(cfg, tokens))
    emb = 2 * tokens * cfg.d_model * cfg.vocab
    out["embed"] = 2 * tokens * cfg.d_model          # table lookup ~ O(T*D)
    out["head"] = float(emb)
    if shape.kind == "train":
        # backward ~ 2x forward for matmul-dominated blocks
        for k in ("block", "shared_attn", "head"):
            if k in out:
                out[k] = out[k] * 3.0
    return out


def total_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic whole-step FLOPs (global, all chips).

    Primary source for the roofline compute term: XLA's cost_analysis
    counts ``while`` (scan) bodies ONCE regardless of trip count, so the
    compiled number underestimates by ~n_layers x (verified in
    EXPERIMENTS.md §Dry-run).
    """
    c = layer_costs(cfg, shape)
    L = cfg.n_layers
    f = c["block"] * L + c["embed"] + c["head"]
    if cfg.family == "hybrid" and cfg.attn_every:
        f += c["shared_attn"] * (L // cfg.attn_every)
    return float(f)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6·N·D / 2·N·D convention (N = active params, D = tokens)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic whole-step HBM traffic (global bytes, all chips).

    Weights: fp32 master read + bf16 cast write/read fwd+bwd, grad write,
    two Adam moments read+write.  Activations: layer boundary tensors plus
    recompute traffic under remat.  Decode: params + full cache sweep.
    """
    from repro.models.params import param_bytes as _pb
    pb = float(_pb(cfg))                          # fp32 master bytes
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        weight_traffic = pb * (2 + 1 + 4) + pb / 2 * 2   # masters+adam+bf16
        act_traffic = L * tokens * D * 2 * 8             # carry+internals
        head_traffic = tokens * V * 4 * 3                # logits fwd+bwd
        return weight_traffic + act_traffic + head_traffic
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        weight_traffic = pb / 2                          # bf16 read once
        act_traffic = L * tokens * D * 2 * 4
        kv_traffic = (L * tokens * cfg.n_kv_heads * cfg.head_dim * 2 * 2
                      if cfg.has_attention else 0)
        return weight_traffic + act_traffic + tokens * V * 4 + kv_traffic
    # decode
    B, S = shape.global_batch, shape.seq_len
    weight_traffic = pb / 2
    if cfg.family in ("dense", "vlm", "moe"):
        cache = 2 * L * B * S * cfg.n_kv_heads * cfg.head_dim * 2
    elif cfg.family == "ssm":
        cache = L * B * cfg.d_inner * cfg.d_state * 4 * 2
    else:                                               # hybrid
        G = L // cfg.attn_every
        cache = (2 * G * B * S * cfg.n_kv_heads * cfg.head_dim * 2 +
                 L * B * cfg.n_ssm_heads * cfg.ssm_head_dim *
                 cfg.d_state * 4 * 2)
    return weight_traffic + cache + B * V * 4


def stage_graph_costs(cfg: ModelConfig, shape: ShapeConfig,
                      n_stage_units: int = 16) -> Tuple[List[float], float]:
    """Collapse the layer chain into ~n_stage_units stage weights (FLOPs)
    plus the boundary activation bytes."""
    c = layer_costs(cfg, shape)
    L = cfg.n_layers
    per_unit = max(1, L // n_stage_units)
    units: List[float] = []
    i = 0
    while i < L:
        span = min(per_unit, L - i)
        w = c["block"] * span
        if cfg.family == "hybrid" and cfg.attn_every:
            n_shared = sum(1 for j in range(i, i + span)
                           if (j + 1) % cfg.attn_every == 0)
            w += c["shared_attn"] * n_shared
        units.append(w)
        i += span
    return units, c["act_bytes"]
