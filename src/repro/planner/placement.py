"""HVLB_CC-driven placement of stage graphs onto TPU mesh slices.

The production mesh is carved into pipeline slices ("processors" in the
paper's model).  Slice execution rates come from chips x peak x MFU —
heterogeneity enters through degraded slices (stragglers, mixed
generations).  Links: intra-pod slice boundaries ride ICI; the pod
boundary rides shared DCN (the "gateway" of the paper's Fig. 2 — a slower
shared bus with real contention).

``plan_placement`` runs HSV_CC (baseline) and HVLB_CC (A/B) on the graph
and returns assignments + predicted step makespans.  Re-planning with
measured rates is the framework's straggler-mitigation path: static
re-scheduling, exactly the paper's answer for time-predictable systems.
``backend=`` threads through to the engine's candidate-evaluation layer
("auto"/"scalar"/"vector"/"pallas" — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (HSV_CC, HVLB_CC_A, HVLB_CC_B, Scheduler, Topology,
                        load_balance)
from repro.core.graph import SPG
from repro.core.scheduler import Schedule

from .cost_model import HW


def tpu_slice_topology(n_slices: int = 8, chips_per_slice: int = 64,
                       pods: int = 2, hw: HW = HW(),
                       degraded: Optional[Dict[int, float]] = None
                       ) -> Topology:
    """Slices on a ring of ICI links; one shared DCN bus joins the pods.

    Link speeds are bytes/s; task weights are FLOPs and rates FLOP/s, so
    all schedule times come out in seconds.
    """
    degraded = degraded or {}
    rates = np.array([chips_per_slice * hw.peak_flops * hw.mfu *
                      degraded.get(i, 1.0) for i in range(n_slices)])
    per_pod = n_slices // pods
    links: Dict[str, float] = {}
    routes: Dict[Tuple[int, int], List[Tuple[str, ...]]] = {}
    # ICI boundary link between adjacent slices within a pod; the slice
    # boundary crosses `chips_per_slice`-worth of ICI edge bandwidth.
    ici_boundary = hw.ici_bw * hw.ici_links * np.sqrt(chips_per_slice)
    for i in range(n_slices - 1):
        same_pod = (i // per_pod) == ((i + 1) // per_pod)
        links[f"l{i}"] = ici_boundary if same_pod else hw.dcn_bw * 8
    # single shared DCN bus for any cross-pod hop (contention point)
    links["dcn"] = hw.dcn_bw * 8
    for a in range(n_slices):
        for b in range(a + 1, n_slices):
            if (a // per_pod) == (b // per_pod):
                routes[(a, b)] = [tuple(f"l{i}" for i in range(a, b))]
            else:
                pre = tuple(f"l{i}" for i in range(a, per_pod * (a // per_pod + 1) - 1))
                post = tuple(f"l{i}" for i in range(per_pod * (b // per_pod), b))
                routes[(a, b)] = [pre + ("dcn",) + post]
    return Topology([f"slice{i}" for i in range(n_slices)], rates, links,
                    routes)


@dataclasses.dataclass
class PlacementPlan:
    schedule: Schedule
    algorithm: str
    makespan_s: float
    load_balance: float
    assignment: Dict[int, int]          # stage -> slice

    @property
    def stage_map(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for t, s in self.assignment.items():
            out.setdefault(s, []).append(t)
        return out


def _policy_for(algorithm: str, alpha_max: float):
    if algorithm == "hsv":
        return HSV_CC()
    if algorithm == "hvlb_a":
        return HVLB_CC_A(alpha_max=alpha_max, alpha_step=0.05)
    if algorithm == "hvlb_b":
        return HVLB_CC_B(alpha_max=alpha_max, alpha_step=0.05)
    raise ValueError(algorithm)


def plan_placement(g: SPG, tg: Topology, algorithm: str = "hvlb_b",
                   alpha_max: float = 3.0,
                   engine: str = "compiled",
                   backend: Optional[str] = None) -> PlacementPlan:
    sched = Scheduler(tg, policy=_policy_for(algorithm, alpha_max),
                      engine=engine, backend=backend)
    s = sched.submit(g).schedule
    return PlacementPlan(
        schedule=s, algorithm=algorithm, makespan_s=s.makespan,
        load_balance=load_balance(s),
        assignment={i: int(s.proc[i]) for i in range(g.n)})


def replan(g: SPG, tg: Topology, measured_rates: Sequence[float],
           algorithm: str = "hvlb_b",
           engine: str = "compiled",
           backend: Optional[str] = None) -> PlacementPlan:
    """Straggler mitigation: re-run the static scheduler with observed
    slice rates (the paper's time-predictable alternative to dynamic
    work stealing)."""
    tg2 = Topology(tg.proc_names, np.asarray(measured_rates, float),
                   dict(tg.link_speed), dict(tg.routes),
                   ctml_mode=tg.ctml_mode)
    return plan_placement(g, tg2, algorithm, engine=engine, backend=backend)
