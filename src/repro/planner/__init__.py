from .cost_model import HW, layer_costs, stage_graph_costs
from .placement import PlacementPlan, plan_placement, tpu_slice_topology
from .taskgraph import (model_stage_graph, pipeline_graph,
                        serving_query_graph)
